//! Tape-refactor regression harness.
//!
//! The layer-op tape (`backend/native/layers.rs`) replaced three
//! hand-unrolled fwd+bwd interpreters. Its contract is *bit-compatibility*:
//! same kernels, same operand order, same history-splice points, same
//! gradient-accumulation grouping. This file keeps the pre-refactor
//! interpreters **verbatim** (module [`legacy`] below — only `use` paths
//! changed) and asserts:
//!
//! 1. per-step `to_bits` equality of loss / grads / push / logits between
//!    the tape and the legacy code, across models × programs × losses ×
//!    reg on/off × seeds;
//! 2. bit-identical end-to-end training curves when the whole GAS loop
//!    (partition → halo → history pipeline → Adam) runs on either
//!    interpreter;
//! 3. the tape's curves against **recorded seed curves**
//!    (`rust/tests/data/tape_seed_curves.json`), guarding the refactored
//!    code itself against future drift — not just parity between two
//!    in-tree code paths. Record with `GAS_RECORD_SEED_CURVES=1 cargo
//!    test --test tape_regression` (CI's main-only refresh step seeds the
//!    file the same way when it is absent).

use gas::backend::native::models::StepCtx;
use gas::backend::native::ops::EdgeIndex;
use gas::backend::native::{registry, NativeArtifact};
use gas::baselines::naive_history::gas_config;
use gas::graph::datasets::{Dataset, Profile};
use gas::history::PipelineMode;
use gas::model::ParamStore;
use gas::runtime::manifest::ArtifactSpec;
use gas::runtime::{Executor, Prepared, StepInputs, StepOutputs};
use gas::train::Trainer;
use gas::util::rng::Rng;

/// The pre-refactor interpreters, kept verbatim (imports aside) as the
/// reference the tape must reproduce bit for bit.
mod legacy {
    use gas::backend::native::gemm;
    use gas::backend::native::models::{Params, StepCtx};
    use gas::backend::native::ops;
    use gas::backend::native::spmm;
    use gas::runtime::manifest::ArtifactSpec;
    use gas::runtime::StepOutputs;
    use anyhow::{bail, Result};

    pub fn run_model(cx: &StepCtx, params: &[Vec<f32>]) -> Result<StepOutputs> {
        let p = Params::new(cx.spec, params)?;
        match cx.spec.model.as_str() {
            "gcn" => run_gcn(cx, &p),
            "gcnii" => run_gcnii(cx, &p),
            "gin" => run_gin(cx, &p),
            other => bail!("legacy interpreter covers gcn/gcnii/gin, not {other:?}"),
        }
    }

    fn zero_grads(spec: &ArtifactSpec) -> Vec<Vec<f32>> {
        spec.params
            .iter()
            .map(|p| vec![0f32; p.shape.iter().product()])
            .collect()
    }

    fn concat_sources(h_batch: &[f32], hist_l: &[f32], nb: usize, nh: usize, d: usize) -> Vec<f32> {
        let mut out = vec![0f32; (nb + nh) * d];
        out[..nb * d].copy_from_slice(&h_batch[..nb * d]);
        out[nb * d..].copy_from_slice(&hist_l[..nh * d]);
        out
    }

    fn stack_push(layers: &[&[f32]], nb: usize, hd: usize) -> Vec<f32> {
        let mut out = vec![0f32; layers.len() * nb * hd];
        for (l, h) in layers.iter().enumerate() {
            out[l * nb * hd..(l + 1) * nb * hd].copy_from_slice(&h[..nb * hd]);
        }
        out
    }

    fn run_gcn(cx: &StepCtx, p: &Params) -> Result<StepOutputs> {
        let spec = cx.spec;
        let big_l = spec.layers;
        let (nb, nh, hd) = (spec.nb, spec.nh, spec.hist_dim);
        let rows = cx.rows();
        let full = cx.full();
        let self_w = cx.self_weights();
        let mut dims = vec![spec.h; big_l + 1];
        dims[0] = spec.f;
        dims[big_l] = spec.c;

        // forward, keeping layer inputs + pre-activations for the backward
        let mut srcs: Vec<Vec<f32>> = Vec::with_capacity(big_l - 1); // input of layer l>=1
        let mut pres: Vec<Vec<f32>> = Vec::with_capacity(big_l);
        for l in 0..big_l {
            let (din, dout) = (dims[l], dims[l + 1]);
            let src_l: &[f32] = if l == 0 { cx.x } else { &srcs[l - 1] };
            let z = gemm::matmul(src_l, rows, din, p.get(&format!("w{l}"))?, dout);
            let mut pre = spmm::scatter(cx.edges, &z, dout);
            for v in 0..nb {
                let zr = &z[v * dout..v * dout + dout];
                let pr = &mut pre[v * dout..v * dout + dout];
                for j in 0..dout {
                    pr[j] += self_w[v] * zr[j];
                }
            }
            ops::add_bias(&mut pre, nb, dout, p.get(&format!("b{l}"))?);
            if l + 1 < big_l {
                let h = ops::relu(&pre);
                srcs.push(if full {
                    h
                } else {
                    concat_sources(&h, cx.hist_layer(l), nb, nh, dout)
                });
            }
            pres.push(pre);
        }
        let logits = pres[big_l - 1][..nb * spec.c].to_vec();
        let push_layers: Vec<&[f32]> = srcs.iter().map(|s| s.as_slice()).collect();
        let push = stack_push(&push_layers, nb, hd);

        // backward
        let (task, mut dpre) = cx.task_loss(&logits);
        let mut grads = zero_grads(spec);
        for l in (0..big_l).rev() {
            let (din, dout) = (dims[l], dims[l + 1]);
            let src_l: &[f32] = if l == 0 { cx.x } else { &srcs[l - 1] };
            ops::colsum_acc(&dpre, nb, dout, &mut grads[p.idx(&format!("b{l}"))?]);
            let mut dz = vec![0f32; rows * dout];
            spmm::scatter_t_acc(cx.edges, &dpre, dout, &mut dz);
            for v in 0..nb {
                let dr = &dpre[v * dout..v * dout + dout];
                let zr = &mut dz[v * dout..v * dout + dout];
                for j in 0..dout {
                    zr[j] += self_w[v] * dr[j];
                }
            }
            gemm::matmul_at_b_acc(
                src_l,
                rows,
                din,
                &dz,
                dout,
                &mut grads[p.idx(&format!("w{l}"))?],
            );
            if l > 0 {
                let dsrc = gemm::matmul_bt(&dz, rows, dout, p.get(&format!("w{l}"))?, din);
                // history rows are inputs: gradient stops at the batch rows
                dpre = ops::relu_bwd(&dsrc[..nb * din], &pres[l - 1][..nb * din]);
            }
        }
        Ok(StepOutputs { loss: task, grads, push, logits })
    }

    fn run_gcnii(cx: &StepCtx, p: &Params) -> Result<StepOutputs> {
        let spec = cx.spec;
        let big_l = spec.layers;
        let (nb, nh, hdim) = (spec.nb, spec.nh, spec.h);
        let rows = cx.rows();
        let full = cx.full();
        let (alpha, lam) = (cx.alpha, cx.lam);
        let self_w = cx.self_weights();
        let betas: Vec<f32> = (1..=big_l).map(|l| (lam / l as f32 + 1.0).ln()).collect();
        let w_stack = p.get("w_stack")?;
        let reg_on = cx.reg_on();

        // input projection (exact for batch AND halo rows)
        let mut t0 = gemm::matmul(cx.x, rows, spec.f, p.get("w_in")?, hdim);
        ops::add_bias(&mut t0, rows, hdim, p.get("b_in")?);
        let h0 = ops::relu(&t0);

        // forward scan
        let mut outs: Vec<Vec<f32>> = Vec::with_capacity(big_l); // h_1..h_L [nb, hdim]
        let mut hns: Vec<Vec<f32>> = Vec::with_capacity(big_l);
        let mut pres: Vec<Vec<f32>> = Vec::with_capacity(big_l);
        let mut hns_p: Vec<Vec<f32>> = Vec::new();
        let mut pres_p: Vec<Vec<f32>> = Vec::new();
        let mut outs_p: Vec<Vec<f32>> = Vec::new();
        let mut reg = 0f32;
        for l in 0..big_l {
            let beta = betas[l];
            let wl = &w_stack[l * hdim * hdim..(l + 1) * hdim * hdim];
            let h_prev: &[f32] = if l == 0 { &h0 } else { &outs[l - 1] };
            let srcs: Vec<f32> = if full {
                h_prev[..rows * hdim].to_vec()
            } else if l == 0 {
                // layer-1 halo sources are the exact h0 rows (no staleness)
                h0.clone()
            } else {
                concat_sources(h_prev, cx.hist_layer(l - 1), nb, nh, hdim)
            };
            let layer_fwd = |s: &[f32]| -> (Vec<f32>, Vec<f32>, Vec<f32>) {
                let mut prop = spmm::scatter(cx.edges, s, hdim);
                for v in 0..nb {
                    let sr = &s[v * hdim..v * hdim + hdim];
                    let pr = &mut prop[v * hdim..v * hdim + hdim];
                    for j in 0..hdim {
                        pr[j] += self_w[v] * sr[j];
                    }
                }
                let mut hn = prop;
                for v in 0..nb * hdim {
                    hn[v] = (1.0 - alpha) * hn[v] + alpha * h0[v];
                }
                let q = gemm::matmul(&hn, nb, hdim, wl, hdim);
                let mut pre = vec![0f32; nb * hdim];
                for i in 0..nb * hdim {
                    pre[i] = (1.0 - beta) * hn[i] + beta * q[i];
                }
                let out = ops::relu(&pre);
                (hn, pre, out)
            };
            let (hn, pre, out) = layer_fwd(&srcs);
            if reg_on {
                let srcs_p = cx.perturb(&srcs, rows, hdim);
                let (hn_p, pre_p, out_p) = layer_fwd(&srcs_p);
                let mut acc = 0f64;
                for i in 0..nb * hdim {
                    let d = (out[i] - out_p[i]) as f64;
                    acc += d * d;
                }
                reg += (acc / nb as f64) as f32;
                hns_p.push(hn_p);
                pres_p.push(pre_p);
                outs_p.push(out_p);
            }
            hns.push(hn);
            pres.push(pre);
            outs.push(out);
        }
        let mut logits = gemm::matmul(&outs[big_l - 1], nb, hdim, p.get("w_out")?, spec.c);
        ops::add_bias(&mut logits, nb, spec.c, p.get("b_out")?);
        let push_layers: Vec<&[f32]> = outs[..big_l - 1].iter().map(|o| o.as_slice()).collect();
        let push = stack_push(&push_layers, nb, spec.hist_dim);

        // backward
        let (task, dlogits) = cx.task_loss(&logits);
        let loss_val = task + cx.reg_lambda * reg;
        let mut grads = zero_grads(spec);
        gemm::matmul_at_b_acc(
            &outs[big_l - 1],
            nb,
            hdim,
            &dlogits,
            spec.c,
            &mut grads[p.idx("w_out")?],
        );
        ops::colsum_acc(&dlogits, nb, spec.c, &mut grads[p.idx("b_out")?]);
        let mut dh = gemm::matmul_bt(&dlogits, nb, spec.c, p.get("w_out")?, hdim);
        let mut dh0 = vec![0f32; rows * hdim];
        let ws_idx = p.idx("w_stack")?;
        for l in (0..big_l).rev() {
            let beta = betas[l];
            let wl = &w_stack[l * hdim * hdim..(l + 1) * hdim * hdim];
            let mut dout = dh;
            let mut dout_p: Option<Vec<f32>> = None;
            if reg_on {
                let coef = cx.reg_lambda * 2.0 / nb as f32;
                let mut dp = vec![0f32; nb * hdim];
                for i in 0..nb * hdim {
                    let g = coef * (outs[l][i] - outs_p[l][i]);
                    dout[i] += g;
                    dp[i] = -g;
                }
                dout_p = Some(dp);
            }
            let mut dsrc = vec![0f32; rows * hdim];
            let mut branch =
                |do_b: &[f32], hn_b: &[f32], pre_b: &[f32], grads: &mut Vec<Vec<f32>>| {
                    let dpre = ops::relu_bwd(do_b, pre_b);
                    let mut dq = vec![0f32; nb * hdim];
                    for i in 0..nb * hdim {
                        dq[i] = beta * dpre[i];
                    }
                    gemm::matmul_at_b_acc(
                        hn_b,
                        nb,
                        hdim,
                        &dq,
                        hdim,
                        &mut grads[ws_idx][l * hdim * hdim..(l + 1) * hdim * hdim],
                    );
                    let mut dhn = gemm::matmul_bt(&dq, nb, hdim, wl, hdim);
                    for i in 0..nb * hdim {
                        dhn[i] += (1.0 - beta) * dpre[i];
                    }
                    for i in 0..nb * hdim {
                        dh0[i] += alpha * dhn[i];
                    }
                    let mut dprop = dhn;
                    for v in dprop.iter_mut() {
                        *v *= 1.0 - alpha;
                    }
                    spmm::scatter_t_acc(cx.edges, &dprop, hdim, &mut dsrc);
                    for v in 0..nb {
                        let dr = &dprop[v * hdim..v * hdim + hdim];
                        let sr = &mut dsrc[v * hdim..v * hdim + hdim];
                        for j in 0..hdim {
                            sr[j] += self_w[v] * dr[j];
                        }
                    }
                };
            branch(&dout, &hns[l], &pres[l], &mut grads);
            if let Some(dp) = dout_p {
                branch(&dp, &hns_p[l], &pres_p[l], &mut grads);
            }
            if l == 0 {
                // h_0 sources: batch rows are h0b, halo rows (gas) are h0 too
                for i in 0..rows * hdim {
                    dh0[i] += dsrc[i];
                }
                dh = Vec::new();
            } else {
                // layers 2..L read halo rows from history: gradient stops there
                dsrc.truncate(nb * hdim);
                dh = dsrc;
            }
        }
        let dt0 = ops::relu_bwd(&dh0, &t0);
        gemm::matmul_at_b_acc(cx.x, rows, spec.f, &dt0, hdim, &mut grads[p.idx("w_in")?]);
        ops::colsum_acc(&dt0, rows, hdim, &mut grads[p.idx("b_in")?]);
        let _ = dh;
        Ok(StepOutputs { loss: loss_val, grads, push, logits })
    }

    struct GinTape {
        pre: Vec<f32>,
        u: Vec<f32>,
        a: Vec<f32>,
        o: Vec<f32>,
    }

    fn run_gin(cx: &StepCtx, p: &Params) -> Result<StepOutputs> {
        let spec = cx.spec;
        let big_l = spec.layers;
        let (nb, nh, h) = (spec.nb, spec.nh, spec.h);
        let rows = cx.rows();
        let full = cx.full();
        let mut dims = vec![h; big_l + 1];
        dims[0] = spec.f;

        let gin_fwd = |l: usize, src_l: &[f32], din: usize| -> Result<GinTape> {
            let eps = p.get(&format!("eps{l}"))?[0];
            let mut pre = spmm::scatter(cx.edges, src_l, din);
            for i in 0..nb * din {
                pre[i] += (1.0 + eps) * src_l[i];
            }
            let mut u = gemm::matmul(&pre, nb, din, p.get(&format!("mlp{l}_w1"))?, h);
            ops::add_bias(&mut u, nb, h, p.get(&format!("mlp{l}_b1"))?);
            let a = ops::relu(&u);
            let mut o = gemm::matmul(&a, nb, h, p.get(&format!("mlp{l}_w2"))?, h);
            ops::add_bias(&mut o, nb, h, p.get(&format!("mlp{l}_b2"))?);
            Ok(GinTape { pre, u, a, o })
        };

        // forward
        let mut srcs: Vec<Vec<f32>> = Vec::with_capacity(big_l); // input of layer l>=1
        let mut tapes: Vec<GinTape> = Vec::with_capacity(big_l);
        let mut tapes_p: Vec<Option<(Vec<f32>, GinTape)>> = Vec::with_capacity(big_l);
        let mut h_last = Vec::new();
        let mut reg = 0f32;
        for l in 0..big_l {
            let din = dims[l];
            let src_l: &[f32] = if l == 0 { cx.x } else { &srcs[l - 1] };
            let tape = gin_fwd(l, src_l, din)?;
            // reg only from layer 1 on: layer-0 inputs are F-dim features
            if cx.reg_on() && l > 0 {
                let src_p = cx.perturb(src_l, rows, din);
                let tape_p = gin_fwd(l, &src_p, din)?;
                let mut acc = 0f64;
                for i in 0..nb * h {
                    let d = (tape.o[i] - tape_p.o[i]) as f64;
                    acc += d * d;
                }
                reg += (acc / nb as f64) as f32;
                tapes_p.push(Some((src_p, tape_p)));
            } else {
                tapes_p.push(None);
            }
            let hn = ops::relu(&tape.o);
            if l + 1 < big_l {
                srcs.push(if full {
                    hn
                } else {
                    concat_sources(&hn, cx.hist_layer(l), nb, nh, h)
                });
            } else {
                h_last = hn;
            }
            tapes.push(tape);
        }
        let mut logits = gemm::matmul(&h_last, nb, h, p.get("head_w")?, spec.c);
        ops::add_bias(&mut logits, nb, spec.c, p.get("head_b")?);
        let push_layers: Vec<&[f32]> = srcs.iter().map(|s| s.as_slice()).collect();
        let push = stack_push(&push_layers, nb, spec.hist_dim);

        // backward
        let (task, dlogits) = cx.task_loss(&logits);
        let loss_val = task + cx.reg_lambda * reg;
        let mut grads = zero_grads(spec);
        gemm::matmul_at_b_acc(&h_last, nb, h, &dlogits, spec.c, &mut grads[p.idx("head_w")?]);
        ops::colsum_acc(&dlogits, nb, spec.c, &mut grads[p.idx("head_b")?]);
        let mut dh = gemm::matmul_bt(&dlogits, nb, spec.c, p.get("head_w")?, h);
        for l in (0..big_l).rev() {
            let din = dims[l];
            let src_l: &[f32] = if l == 0 { cx.x } else { &srcs[l - 1] };
            let tape = &tapes[l];
            let mut do_ = ops::relu_bwd(&dh, &tape.o);
            let mut do_p: Option<Vec<f32>> = None;
            if let Some((_, tape_p)) = &tapes_p[l] {
                let coef = cx.reg_lambda * 2.0 / nb as f32;
                let mut dp = vec![0f32; nb * h];
                for i in 0..nb * h {
                    let g = coef * (tape.o[i] - tape_p.o[i]);
                    do_[i] += g;
                    dp[i] = -g;
                }
                do_p = Some(dp);
            }
            let mut dsrc = vec![0f32; rows * din];
            gin_branch_bwd(cx, p, l, din, &do_, tape, src_l, &mut grads, &mut dsrc)?;
            if let (Some(dp), Some((src_p, tape_p))) = (do_p, &tapes_p[l]) {
                gin_branch_bwd(cx, p, l, din, &dp, tape_p, src_p, &mut grads, &mut dsrc)?;
            }
            if l > 0 {
                // dsrc[:nb] is the gradient w.r.t. h_l = relu(o_{l-1}); the
                // relu' mask is applied at the top of the next iteration
                dsrc.truncate(nb * din);
                dh = dsrc;
            }
        }
        Ok(StepOutputs { loss: loss_val, grads, push, logits })
    }

    #[allow(clippy::too_many_arguments)]
    fn gin_branch_bwd(
        cx: &StepCtx,
        p: &Params,
        l: usize,
        din: usize,
        do_: &[f32],
        tape: &GinTape,
        src_l: &[f32],
        grads: &mut [Vec<f32>],
        dsrc: &mut [f32],
    ) -> Result<()> {
        let spec = cx.spec;
        let (nb, h) = (spec.nb, spec.h);
        let eps = p.get(&format!("eps{l}"))?[0];
        gemm::matmul_at_b_acc(&tape.a, nb, h, do_, h, &mut grads[p.idx(&format!("mlp{l}_w2"))?]);
        ops::colsum_acc(do_, nb, h, &mut grads[p.idx(&format!("mlp{l}_b2"))?]);
        let da = gemm::matmul_bt(do_, nb, h, p.get(&format!("mlp{l}_w2"))?, h);
        let du = ops::relu_bwd(&da, &tape.u);
        gemm::matmul_at_b_acc(
            &tape.pre,
            nb,
            din,
            &du,
            h,
            &mut grads[p.idx(&format!("mlp{l}_w1"))?],
        );
        ops::colsum_acc(&du, nb, h, &mut grads[p.idx(&format!("mlp{l}_b1"))?]);
        let dpre = gemm::matmul_bt(&du, nb, h, p.get(&format!("mlp{l}_w1"))?, din);
        let mut deps = 0f32;
        for i in 0..nb * din {
            deps += dpre[i] * src_l[i];
        }
        grads[p.idx(&format!("eps{l}"))?][0] += deps;
        for i in 0..nb * din {
            dsrc[i] += (1.0 + eps) * dpre[i];
        }
        spmm::scatter_t_acc(cx.edges, &dpre, din, dsrc);
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// a legacy-backed Executor, so the whole GAS loop can run on the old code
// ---------------------------------------------------------------------------

struct LegacyStatics {
    x: Vec<f32>,
    deg: Vec<f32>,
    labels_i: Vec<i32>,
    labels_f: Vec<f32>,
    mask: Vec<f32>,
    edges: EdgeIndex,
    noise: Option<Vec<f32>>,
}

struct LegacyArtifact {
    spec: ArtifactSpec,
}

impl LegacyArtifact {
    fn n_src(&self) -> usize {
        if self.spec.is_full() {
            self.spec.nb
        } else {
            self.spec.nt
        }
    }

    fn statics(&self, inp: &StepInputs, cache_noise: bool) -> anyhow::Result<LegacyStatics> {
        let edges = EdgeIndex::build(
            inp.edge_src,
            inp.edge_dst,
            inp.edge_w,
            self.n_src(),
            self.spec.nb,
        )?;
        Ok(LegacyStatics {
            x: inp.x.to_vec(),
            deg: inp.deg.to_vec(),
            labels_i: inp.labels_i.map(|l| l.to_vec()).unwrap_or_default(),
            labels_f: inp.labels_f.map(|l| l.to_vec()).unwrap_or_default(),
            mask: inp.label_mask.to_vec(),
            edges,
            noise: if cache_noise { Some(inp.noise.to_vec()) } else { None },
        })
    }

    fn run_on(
        &self,
        params: &[Vec<f32>],
        st: &LegacyStatics,
        hist: &[f32],
        noise: &[f32],
        reg_lambda: f32,
    ) -> anyhow::Result<StepOutputs> {
        let cx = StepCtx {
            spec: &self.spec,
            edges: &st.edges,
            x: &st.x,
            deg: &st.deg,
            labels_i: &st.labels_i,
            labels_f: &st.labels_f,
            mask: &st.mask,
            hist,
            noise,
            reg_lambda,
            alpha: 0.1,
            lam: 1.0,
        };
        legacy::run_model(&cx, params)
    }
}

impl Executor for LegacyArtifact {
    fn spec(&self) -> &ArtifactSpec {
        &self.spec
    }

    fn prepare_static(&self, inp: &StepInputs, cache_noise: bool) -> anyhow::Result<Prepared> {
        Ok(Prepared::new(self.statics(inp, cache_noise)?))
    }

    fn run_prepared(
        &self,
        params: &[Vec<f32>],
        statics: &Prepared,
        hist: &[f32],
        noise: &[f32],
        reg_lambda: f32,
    ) -> anyhow::Result<StepOutputs> {
        let st = statics.downcast::<LegacyStatics>()?;
        let noise = st.noise.as_deref().unwrap_or(noise);
        self.run_on(params, st, hist, noise, reg_lambda)
    }

    fn run(&self, params: &[Vec<f32>], inp: &StepInputs) -> anyhow::Result<StepOutputs> {
        let st = self.statics(inp, false)?;
        self.run_on(params, &st, inp.hist, inp.noise, inp.reg_lambda)
    }
}

// ---------------------------------------------------------------------------
// 1. per-step bitwise parity on random inputs
// ---------------------------------------------------------------------------

struct CaseInputs {
    x: Vec<f32>,
    e_src: Vec<i32>,
    e_dst: Vec<i32>,
    e_w: Vec<f32>,
    hist: Vec<f32>,
    deg: Vec<f32>,
    labels_i: Vec<i32>,
    labels_f: Vec<f32>,
    mask: Vec<f32>,
    noise: Vec<f32>,
}

fn gen_inputs(spec: &ArtifactSpec, seed: u64) -> CaseInputs {
    let mut rng = Rng::new(seed);
    let rows = if spec.is_full() { spec.nb } else { spec.nt };
    let x: Vec<f32> = (0..rows * spec.f).map(|_| rng.normal_f32() * 0.6).collect();
    let n_real = 14.min(spec.e);
    let (mut e_src, mut e_dst, mut e_w) = (Vec::new(), Vec::new(), Vec::new());
    for _ in 0..n_real {
        e_src.push(rng.below(rows) as i32);
        e_dst.push(rng.below(spec.nb) as i32);
        e_w.push(0.3 + rng.f32() * 0.7);
    }
    e_src.resize(spec.e, 0);
    e_dst.resize(spec.e, 0);
    e_w.resize(spec.e, 0.0);
    let hist: Vec<f32> = (0..spec.hist_layers() * spec.nh * spec.hist_dim)
        .map(|_| rng.normal_f32() * 0.4)
        .collect();
    let deg: Vec<f32> = (0..rows).map(|_| (1 + rng.below(4)) as f32).collect();
    let labels_i: Vec<i32> = (0..spec.nb).map(|_| rng.below(spec.c) as i32).collect();
    let labels_f: Vec<f32> = (0..spec.nb * spec.c)
        .map(|_| if rng.chance(0.4) { 1.0 } else { 0.0 })
        .collect();
    let mut mask: Vec<f32> =
        (0..spec.nb).map(|_| if rng.chance(0.7) { 1.0 } else { 0.0 }).collect();
    mask[0] = 1.0;
    let noise: Vec<f32> = (0..rows * spec.h.max(spec.hist_dim))
        .map(|_| rng.normal_f32() * 0.15)
        .collect();
    CaseInputs { x, e_src, e_dst, e_w, hist, deg, labels_i, labels_f, mask, noise }
}

fn step_inputs<'a>(spec: &ArtifactSpec, c: &'a CaseInputs, reg: f32) -> StepInputs<'a> {
    StepInputs {
        x: &c.x,
        edge_src: &c.e_src,
        edge_dst: &c.e_dst,
        edge_w: &c.e_w,
        hist: &c.hist,
        labels_i: if spec.loss == "ce" { Some(&c.labels_i) } else { None },
        labels_f: if spec.loss == "bce" { Some(&c.labels_f) } else { None },
        label_mask: &c.mask,
        deg: &c.deg,
        noise: &c.noise,
        reg_lambda: reg,
    }
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn step_outputs_match_legacy_bitwise() {
    let configs: [(&str, usize, &str, &str, f32); 11] = [
        ("gcn", 2, "gas", "ce", 0.0),
        ("gcn", 3, "full", "ce", 0.0),
        ("gcn", 2, "gas", "bce", 0.0),
        ("gcnii", 3, "gas", "ce", 0.0),
        ("gcnii", 3, "gas", "ce", 0.3),
        ("gcnii", 2, "full", "ce", 0.0),
        ("gcnii", 2, "gas", "bce", 0.3),
        ("gin", 2, "gas", "ce", 0.0),
        ("gin", 3, "gas", "ce", 0.3),
        ("gin", 2, "full", "ce", 0.0),
        ("gin", 2, "gas", "bce", 0.0),
    ];
    for (model, layers, program, loss, reg) in configs {
        for seed in [1u64, 2, 3] {
            let spec = registry::test_spec(model, layers, program, 5, 3, 24, 3, 4, 3, loss);
            let case = gen_inputs(&spec, seed ^ 0xcafe);
            let params = ParamStore::init(&spec.params, seed ^ 0x51ab).unwrap();
            let inp = step_inputs(&spec, &case, reg);
            let tape_art = NativeArtifact::new(spec.clone()).unwrap();
            let tape_out = tape_art.run(&params.tensors, &inp).unwrap();
            let legacy_art = LegacyArtifact { spec: spec.clone() };
            let legacy_out = legacy_art.run(&params.tensors, &inp).unwrap();
            let tag = format!("{model}/{layers}/{program}/{loss} reg={reg} seed={seed}");
            assert_eq!(tape_out.loss.to_bits(), legacy_out.loss.to_bits(), "{tag}: loss");
            assert_eq!(bits(&tape_out.push), bits(&legacy_out.push), "{tag}: push");
            assert_eq!(bits(&tape_out.logits), bits(&legacy_out.logits), "{tag}: logits");
            assert_eq!(tape_out.grads.len(), legacy_out.grads.len(), "{tag}");
            for (i, (gt, gl)) in tape_out.grads.iter().zip(legacy_out.grads.iter()).enumerate() {
                assert_eq!(bits(gt), bits(gl), "{tag}: grad {}", spec.params[i].name);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// 2. + 3. end-to-end curves: tape vs legacy executor, and vs the recorded
//    seed curves committed alongside the tests
// ---------------------------------------------------------------------------

fn synth_profile() -> Profile {
    Profile {
        name: "tape_reg_pp".into(),
        kind: "planted".into(),
        n: 400,
        f: 16,
        c: 4,
        avg_deg: 6.0,
        multilabel: false,
        train_frac: 0.5,
        val_frac: 0.2,
        homophily: 0.9,
        feat_noise: 0.5,
        parts: 4,
        paper_n: 400,
        seed: 11,
    }
}

/// One deterministic (Serial pipeline, depth 1) short training run on the
/// given executor; returns the per-epoch loss curve.
fn run_curves(ds: &Dataset, art: &dyn Executor, reg: f32) -> (Vec<f64>, Vec<f64>) {
    let mut cfg = gas_config(6, 0.01, reg, 9);
    cfg.pipeline = PipelineMode::Serial; // concurrency reorders pushes
    cfg.pull_depth = 1;
    cfg.eval_every = 2;
    let mut tr = Trainer::new(ds, art, cfg).unwrap();
    let r = tr.train().unwrap();
    (r.loss.values.clone(), r.val_acc.values.clone())
}

/// The three curve configurations the harness pins: one per legacy model
/// family, gcnii with the Lipschitz branch active.
fn curve_configs() -> Vec<(&'static str, usize, f32)> {
    vec![("gcn", 2, 0.0), ("gcnii", 3, 0.02), ("gin", 3, 0.0)]
}

fn tape_curves() -> Vec<(String, Vec<f64>, Vec<f64>)> {
    let profile = synth_profile();
    let ds = Dataset::generate(&profile);
    curve_configs()
        .into_iter()
        .map(|(model, layers, reg)| {
            let spec = registry::spec_for_profile(&profile, model, layers, "gas", "").unwrap();
            let art = NativeArtifact::new(spec).unwrap();
            let (loss, val) = run_curves(&ds, &art, reg);
            (model.to_string(), loss, val)
        })
        .collect()
}

#[test]
fn e2e_curves_match_legacy_bitwise() {
    let profile = synth_profile();
    let ds = Dataset::generate(&profile);
    for (model, layers, reg) in curve_configs() {
        let spec = registry::spec_for_profile(&profile, model, layers, "gas", "").unwrap();
        let tape_art = NativeArtifact::new(spec.clone()).unwrap();
        let (tape_loss, tape_val) = run_curves(&ds, &tape_art, reg);
        let legacy_art = LegacyArtifact { spec };
        let (leg_loss, leg_val) = run_curves(&ds, &legacy_art, reg);
        let lb = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<u64>>();
        assert_eq!(lb(&tape_loss), lb(&leg_loss), "{model}: loss curves diverged");
        assert_eq!(lb(&tape_val), lb(&leg_val), "{model}: val curves diverged");
        // the runs actually trained (a flat curve would vacuously match)
        assert!(
            tape_loss.last().unwrap() < tape_loss.first().unwrap(),
            "{model}: loss did not decrease"
        );
    }
}

const SEED_CURVES: &str =
    concat!(env!("CARGO_MANIFEST_DIR"), "/rust/tests/data/tape_seed_curves.json");

#[test]
fn seed_curves_match_recorded() {
    let curves = tape_curves();
    if std::env::var("GAS_RECORD_SEED_CURVES").is_ok() {
        let mut body = String::from("{\n  \"curves\": {\n");
        for (i, (model, loss, _)) in curves.iter().enumerate() {
            let hex: Vec<String> =
                loss.iter().map(|v| format!("\"{:016x}\"", v.to_bits())).collect();
            body.push_str(&format!("    \"{model}\": [{}]", hex.join(", ")));
            body.push_str(if i + 1 < curves.len() { ",\n" } else { "\n" });
        }
        body.push_str("  }\n}\n");
        std::fs::create_dir_all(std::path::Path::new(SEED_CURVES).parent().unwrap()).unwrap();
        std::fs::write(SEED_CURVES, body).unwrap();
        eprintln!("recorded seed curves to {SEED_CURVES}");
        return;
    }
    let Ok(text) = std::fs::read_to_string(SEED_CURVES) else {
        // not recorded yet (the main-only CI refresh step seeds it); the
        // legacy-parity test above still guards the refactor meanwhile
        eprintln!(
            "no recorded seed curves at {SEED_CURVES}; run with \
             GAS_RECORD_SEED_CURVES=1 to record"
        );
        return;
    };
    let j = gas::util::json::Json::parse(&text).expect("parsing recorded seed curves");
    let rec = j.get("curves").unwrap();
    for (model, loss, _) in &curves {
        let want: Vec<u64> = rec
            .get(model)
            .unwrap_or_else(|_| panic!("recorded curves missing {model}"))
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| u64::from_str_radix(v.as_str().unwrap(), 16).unwrap())
            .collect();
        let got: Vec<u64> = loss.iter().map(|v| v.to_bits()).collect();
        assert_eq!(got, want, "{model}: tape loss curve drifted from the recorded seed");
    }
}
