//! Crash tolerance end to end: a training run killed at an epoch
//! boundary and resumed from its checkpoint manifest must be
//! indistinguishable from one that was never interrupted.
//!
//! "Indistinguishable" is the bit-level contract of the tape-regression
//! schedule (Serial pipeline, pull_depth=1): every curve point, every
//! parameter tensor, and every history shard — clocks, probe
//! accumulators, and encoded rows alike — compare `to_bits`-equal. The
//! sweep crosses kill epoch x codec {f32,f16,int8} x medium {ram,mmap}
//! x schedule policy, plus a checkpoint_every > 1 arm where the kill
//! lands *past* the last manifest and resume has to replay an epoch.
//!
//! The fault-injection half covers the degraded paths: a poisoned push
//! worker surfaces as a typed error from `train()` (never a process
//! abort), a truncated shard file is re-zeroed under
//! `BackingSpec::with_recovery` and training continues with finite,
//! decreasing loss (and is refused loudly without it), and a corrupt
//! manifest fails resume with a CRC complaint rather than silently
//! training from scratch.

use gas::backend::native::{registry, NativeArtifact};
use gas::baselines::naive_history::gas_config;
use gas::config::FaultPlan;
use gas::graph::datasets::{Dataset, Profile};
use gas::history::{BackingSpec, Codec, PipelineMode};
use gas::sched::SchedulePolicy;
use gas::train::checkpoint::manifest_path;
use gas::train::{TrainConfig, Trainer};
use std::path::PathBuf;

fn tmp(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("gas-ckpt-{tag}-{}", std::process::id()))
}

fn fbits(v: &[f64]) -> Vec<u64> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn pbits(params: &[Vec<f32>]) -> Vec<Vec<u32>> {
    params.iter().map(|t| t.iter().map(|x| x.to_bits()).collect()).collect()
}

fn synth_profile() -> Profile {
    Profile {
        name: "ckpt_pp".into(),
        kind: "planted".into(),
        n: 400,
        f: 16,
        c: 4,
        avg_deg: 6.0,
        multilabel: false,
        train_frac: 0.5,
        val_frac: 0.2,
        homophily: 0.9,
        feat_noise: 0.5,
        parts: 4,
        paper_n: 400,
        seed: 11,
    }
}

/// The bit-deterministic schedule: Serial pipeline, one-step lookahead.
fn serial_cfg(backing: BackingSpec) -> TrainConfig {
    let mut cfg = gas_config(6, 0.01, 0.02, 9);
    cfg.pipeline = PipelineMode::Serial;
    cfg.pull_depth = 1;
    cfg.eval_every = 2;
    cfg.history_backing = backing;
    cfg
}

/// One kill-and-resume arm: run uninterrupted as the reference, run
/// again stopping after `kill_epoch` (the checkpoint written at that
/// boundary — or an earlier one, when `every > 1` — is all that
/// survives), then resume in a fresh Trainer and compare everything.
fn assert_kill_resume_bit_identical(
    tag: &str,
    codec: Codec,
    mmap_medium: bool,
    kill_epoch: usize,
    every: usize,
    policy: SchedulePolicy,
) {
    let profile = synth_profile();
    let ds = Dataset::generate(&profile);
    let spec = registry::spec_for_profile(&profile, "gcn", 2, "gas", "").unwrap();
    let art = NativeArtifact::new(spec).unwrap();
    let ck_dir = tmp(&format!("{tag}-manifest"));
    let shards_a = tmp(&format!("{tag}-shards-a"));
    let shards_b = tmp(&format!("{tag}-shards-b"));
    let backing = |dir: &PathBuf| {
        if mmap_medium {
            BackingSpec::mmap(dir, false).with_codec(codec)
        } else {
            BackingSpec::ram().with_codec(codec)
        }
    };

    // reference: never interrupted, never checkpointed
    let mut cfg_a = serial_cfg(backing(&shards_a));
    cfg_a.sched_policy = policy;
    let mut tr_a = Trainer::new(&ds, &art, cfg_a).unwrap();
    let r_a = tr_a.train().unwrap();
    assert!(
        r_a.loss.values.last().unwrap() < r_a.loss.values.first().unwrap(),
        "{tag}: reference run did not train"
    );

    // killed run: checkpoints every `every` epochs, stops after
    // `kill_epoch` (stand-in for SIGKILL: the Trainer is dropped and
    // only what `save_checkpoint` persisted survives into the resume)
    let mut cfg_b = serial_cfg(backing(&shards_b));
    cfg_b.sched_policy = policy;
    cfg_b.checkpoint_dir = Some(ck_dir.clone());
    cfg_b.checkpoint_every = every;
    cfg_b.stop_after_epoch = Some(kill_epoch);
    let mut tr_b = Trainer::new(&ds, &art, cfg_b).unwrap();
    let r_b = tr_b.train().unwrap();
    assert!(
        r_b.loss.values.len() < r_a.loss.values.len(),
        "{tag}: killed run was supposed to stop early"
    );
    drop(tr_b);

    // resumed run: same config, --resume; finishes the remaining epochs
    let mut cfg_c = serial_cfg(backing(&shards_b));
    cfg_c.sched_policy = policy;
    cfg_c.checkpoint_dir = Some(ck_dir.clone());
    cfg_c.checkpoint_every = every;
    cfg_c.resume = true;
    let mut tr_c = Trainer::new(&ds, &art, cfg_c).unwrap();
    let r_c = tr_c.train().unwrap();

    // every observable the uninterrupted run produced, bit for bit
    assert_eq!(fbits(&r_a.loss.values), fbits(&r_c.loss.values), "{tag}: loss curve");
    assert_eq!(fbits(&r_a.train_acc.values), fbits(&r_c.train_acc.values), "{tag}: train acc");
    assert_eq!(fbits(&r_a.val_acc.values), fbits(&r_c.val_acc.values), "{tag}: val acc");
    assert_eq!(fbits(&r_a.test_acc.values), fbits(&r_c.test_acc.values), "{tag}: test acc");
    assert_eq!(
        r_a.test_at_best_val.to_bits(),
        r_c.test_at_best_val.to_bits(),
        "{tag}: test@best-val"
    );
    assert_eq!(
        fbits(&r_a.staleness_epoch.values),
        fbits(&r_c.staleness_epoch.values),
        "{tag}: staleness curve"
    );
    assert_eq!(fbits(&r_a.staleness), fbits(&r_c.staleness), "{tag}: final staleness");
    assert_eq!(
        fbits(&r_a.quant_err_max.values),
        fbits(&r_c.quant_err_max.values),
        "{tag}: quant telemetry"
    );
    assert_eq!(r_a.steps, r_c.steps, "{tag}: step count");
    assert_eq!(
        pbits(&tr_a.params.tensors),
        pbits(&tr_c.params.tensors),
        "{tag}: parameter tensors diverged"
    );
    // the history itself: staleness clocks, probe accumulators, and the
    // encoded rows in the backing's own byte encoding
    let hist_a = tr_a.with_history(|s| s.export_state());
    let hist_c = tr_c.with_history(|s| s.export_state());
    assert_eq!(hist_a, hist_c, "{tag}: history shard state diverged");

    drop((tr_a, tr_c));
    for d in [&ck_dir, &shards_a, &shards_b] {
        let _ = std::fs::remove_dir_all(d);
    }
}

#[test]
fn kill_and_resume_f32_ram() {
    assert_kill_resume_bit_identical(
        "f32-ram", Codec::F32, false, 3, 1, SchedulePolicy::RoundRobin,
    );
}

#[test]
fn kill_and_resume_f32_mmap_kill_at_first_epoch() {
    assert_kill_resume_bit_identical(
        "f32-mmap", Codec::F32, true, 1, 1, SchedulePolicy::RoundRobin,
    );
}

#[test]
fn kill_and_resume_f16_ram() {
    assert_kill_resume_bit_identical(
        "f16-ram", Codec::F16, false, 2, 1, SchedulePolicy::RoundRobin,
    );
}

#[test]
fn kill_and_resume_f16_mmap_kill_at_last_epoch() {
    // kill after epoch 5 of 6: resume replays exactly one epoch
    assert_kill_resume_bit_identical(
        "f16-mmap", Codec::F16, true, 5, 1, SchedulePolicy::RoundRobin,
    );
}

#[test]
fn kill_and_resume_int8_ram_kill_past_last_manifest() {
    // checkpoint every 2, killed after epoch 3: the newest manifest is
    // from epoch 2, so resume re-runs epoch 3 — the replay must land on
    // the same bits the first attempt produced
    assert_kill_resume_bit_identical(
        "int8-ram", Codec::Int8, false, 3, 2, SchedulePolicy::RoundRobin,
    );
}

#[test]
fn kill_and_resume_int8_mmap_staleness_schedule() {
    // the staleness-ordered policy carries cross-epoch scheduler state
    // (scores, order, its own rng) — all of it rides in the manifest
    assert_kill_resume_bit_identical(
        "int8-mmap", Codec::Int8, true, 3, 1, SchedulePolicy::StalenessOrdered,
    );
}

#[test]
fn poisoned_push_worker_is_a_training_error_not_an_abort() {
    let profile = synth_profile();
    let ds = Dataset::generate(&profile);
    let spec = registry::spec_for_profile(&profile, "gcn", 2, "gas", "").unwrap();
    let art = NativeArtifact::new(spec).unwrap();
    let mut cfg = serial_cfg(BackingSpec::ram());
    cfg.pipeline = PipelineMode::Concurrent;
    cfg.pull_depth = 2;
    cfg.fault = Some(FaultPlan::PushWorkerPanicAtStep(3));
    let mut tr = Trainer::new(&ds, &art, cfg).unwrap();
    let err = tr.train().expect_err("poisoned worker must fail the run");
    let msg = format!("{err:#}");
    assert!(
        msg.contains("worker"),
        "expected a typed worker-death error, got: {msg}"
    );
    // dropping the trainer (and with it the dead pipeline) must not panic
    drop(tr);
}

#[test]
fn truncated_shard_recovers_under_recovery_mode_and_is_refused_without() {
    let profile = synth_profile();
    let ds = Dataset::generate(&profile);
    let spec = registry::spec_for_profile(&profile, "gcn", 2, "gas", "").unwrap();
    let art = NativeArtifact::new(spec).unwrap();
    let dir = tmp("recover-shards");

    // seed the shard files with a healthy flushed run
    let mut tr = Trainer::new(&ds, &art, serial_cfg(BackingSpec::mmap(&dir, false))).unwrap();
    tr.train().unwrap();
    drop(tr);

    // without recovery mode, the damaged shard is a loud constructor
    // error (the TruncateShard fault clips shard001.bin before the
    // store reopens it, simulating a torn write-behind flush)
    let mut cfg = serial_cfg(BackingSpec::mmap(&dir, true));
    cfg.fault = Some(FaultPlan::TruncateShard(1));
    assert!(
        Trainer::new(&ds, &art, cfg).is_err(),
        "truncated shard must not reopen silently without recovery mode"
    );

    // with recovery mode: the bad shard is re-zeroed, its rows pinned
    // max-stale, and training proceeds to a finite, decreasing loss
    let mut cfg = serial_cfg(BackingSpec::mmap(&dir, true).with_recovery(true));
    cfg.fault = Some(FaultPlan::TruncateShard(1));
    let mut tr = Trainer::new(&ds, &art, cfg).unwrap();
    assert_eq!(
        tr.with_history(|s| s.recovered_shards()),
        vec![1],
        "exactly the damaged shard should be in recovery"
    );
    let r = tr.train().unwrap();
    assert!(
        r.loss.values.iter().all(|v| v.is_finite()),
        "recovered run produced a non-finite loss"
    );
    assert!(
        r.loss.values.last().unwrap() < r.loss.values.first().unwrap(),
        "recovered run did not converge"
    );
    drop(tr);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn corrupt_manifest_fails_resume_loudly() {
    let profile = synth_profile();
    let ds = Dataset::generate(&profile);
    let spec = registry::spec_for_profile(&profile, "gcn", 2, "gas", "").unwrap();
    let art = NativeArtifact::new(spec).unwrap();
    let dir = tmp("bad-manifest");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(manifest_path(&dir), b"not a checkpoint at all").unwrap();
    let mut cfg = serial_cfg(BackingSpec::ram());
    cfg.checkpoint_dir = Some(dir.clone());
    cfg.resume = true;
    let err = match Trainer::new(&ds, &art, cfg) {
        Err(e) => format!("{e:#}"),
        Ok(_) => panic!("corrupt manifest must not silently train from scratch"),
    };
    assert!(
        err.contains("GASK") || err.contains("checkpoint"),
        "expected a manifest-format complaint, got: {err}"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn resume_rejects_a_mismatched_schedule() {
    let profile = synth_profile();
    let ds = Dataset::generate(&profile);
    let spec = registry::spec_for_profile(&profile, "gcn", 2, "gas", "").unwrap();
    let art = NativeArtifact::new(spec).unwrap();
    let dir = tmp("mismatch-manifest");

    let mut cfg = serial_cfg(BackingSpec::ram());
    cfg.checkpoint_dir = Some(dir.clone());
    cfg.stop_after_epoch = Some(2);
    let mut tr = Trainer::new(&ds, &art, cfg).unwrap();
    tr.train().unwrap();
    drop(tr);

    // different seed: the replayed schedule would diverge — refuse
    let mut cfg = serial_cfg(BackingSpec::ram());
    cfg.seed = 123;
    cfg.checkpoint_dir = Some(dir.clone());
    cfg.resume = true;
    assert!(Trainer::new(&ds, &art, cfg).is_err(), "seed mismatch must refuse resume");

    // different codec: the shard payloads are codec-specific — refuse
    let mut cfg = serial_cfg(BackingSpec::ram().with_codec(Codec::F16));
    cfg.checkpoint_dir = Some(dir.clone());
    cfg.resume = true;
    assert!(Trainer::new(&ds, &art, cfg).is_err(), "codec mismatch must refuse resume");
    let _ = std::fs::remove_dir_all(&dir);
}
