//! Offline stand-in for the `xla` PJRT bindings.
//!
//! The GAS coordinator talks to XLA through a narrow surface: parse an
//! HLO-text artifact, compile it on a CPU PJRT client, marshal `Literal`
//! values in and out of `execute`. The real bindings need a multi-GB
//! `libxla_extension` that is not available in the offline build
//! environment, so this crate provides the same types with fully
//! functional host-side literals (creation, reshape, tuple decomposition,
//! typed extraction) and a client whose `execute` fails with a clear
//! error. Everything up to execution — manifest loading, shape checking,
//! literal marshalling, batch assembly, the history engine — runs and is
//! tested against this crate; training additionally requires the real
//! bindings plus AOT-compiled artifacts.

use std::borrow::Borrow;
use std::fmt;

/// Error type mirroring the real bindings' (message-carrying) errors.
#[derive(Debug)]
pub struct Error {
    msg: String,
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.msg)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn err(msg: impl Into<String>) -> Error {
    Error { msg: msg.into() }
}

/// Element dtypes the coordinator uses (f32 tensors, i32 indices/labels).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

impl ElementType {
    pub fn byte_size(self) -> usize {
        4
    }
}

/// Rust-native element types that map onto an [`ElementType`].
pub trait NativeType: Copy {
    const TY: ElementType;
    fn from_le(b: [u8; 4]) -> Self;
    fn to_le(self) -> [u8; 4];
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
    fn from_le(b: [u8; 4]) -> Self {
        f32::from_le_bytes(b)
    }
    fn to_le(self) -> [u8; 4] {
        self.to_le_bytes()
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
    fn from_le(b: [u8; 4]) -> Self {
        i32::from_le_bytes(b)
    }
    fn to_le(self) -> [u8; 4] {
        self.to_le_bytes()
    }
}

#[derive(Debug, Clone, PartialEq)]
enum Repr {
    Dense {
        ty: ElementType,
        dims: Vec<i64>,
        data: Vec<u8>,
    },
    Tuple(Vec<Literal>),
}

/// A host-side tensor (or tuple of tensors) in row-major layout.
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    repr: Repr,
}

impl Literal {
    /// Scalar f32 literal.
    pub fn scalar(v: f32) -> Literal {
        Literal {
            repr: Repr::Dense {
                ty: ElementType::F32,
                dims: Vec::new(),
                data: v.to_le_bytes().to_vec(),
            },
        }
    }

    /// Rank-1 literal from a native slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        let mut bytes = Vec::with_capacity(data.len() * 4);
        for &v in data {
            bytes.extend_from_slice(&v.to_le());
        }
        Literal {
            repr: Repr::Dense {
                ty: T::TY,
                dims: vec![data.len() as i64],
                data: bytes,
            },
        }
    }

    /// Build a literal of `dims` shape directly from raw little-endian bytes.
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        let want: usize = dims.iter().product::<usize>() * ty.byte_size();
        if want != data.len() {
            return Err(err(format!(
                "shape {dims:?} wants {want} bytes, got {}",
                data.len()
            )));
        }
        Ok(Literal {
            repr: Repr::Dense {
                ty,
                dims: dims.iter().map(|&d| d as i64).collect(),
                data: data.to_vec(),
            },
        })
    }

    /// Tuple literal (what executables return).
    pub fn tuple(elems: Vec<Literal>) -> Literal {
        Literal {
            repr: Repr::Tuple(elems),
        }
    }

    /// Number of elements of a dense literal (1 for scalars).
    pub fn element_count(&self) -> usize {
        match &self.repr {
            Repr::Dense { ty, data, .. } => data.len() / ty.byte_size(),
            Repr::Tuple(elems) => elems.iter().map(|e| e.element_count()).sum(),
        }
    }

    /// Same data, new shape; element count must match.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        match &self.repr {
            Repr::Dense { ty, data, .. } => {
                let want: usize = dims.iter().map(|&d| d as usize).product();
                if want != data.len() / ty.byte_size() {
                    return Err(err(format!(
                        "cannot reshape {} elements to {dims:?}",
                        data.len() / ty.byte_size()
                    )));
                }
                Ok(Literal {
                    repr: Repr::Dense {
                        ty: *ty,
                        dims: dims.to_vec(),
                        data: data.clone(),
                    },
                })
            }
            Repr::Tuple(_) => Err(err("cannot reshape a tuple literal")),
        }
    }

    /// Extract a flat typed vector (dtype must match).
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        match &self.repr {
            Repr::Dense { ty, data, .. } => {
                if *ty != T::TY {
                    return Err(err(format!("literal is {ty:?}, requested {:?}", T::TY)));
                }
                Ok(data
                    .chunks_exact(4)
                    .map(|c| T::from_le([c[0], c[1], c[2], c[3]]))
                    .collect())
            }
            Repr::Tuple(_) => Err(err("cannot extract a typed vec from a tuple literal")),
        }
    }

    /// Decompose a tuple literal into its parts.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.repr {
            Repr::Tuple(elems) => Ok(elems),
            Repr::Dense { .. } => Err(err("literal is not a tuple")),
        }
    }
}

/// Parsed (well — carried) HLO module text. jax >= 0.5 emits 64-bit
/// instruction ids, so interchange is text, re-parsed by the backend.
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| err(format!("reading HLO text {path}: {e}")))?;
        Ok(HloModuleProto { text })
    }

    pub fn from_text(text: &str) -> HloModuleProto {
        HloModuleProto {
            text: text.to_string(),
        }
    }

    pub fn text(&self) -> &str {
        &self.text
    }
}

/// An XLA computation wrapping an HLO module.
#[derive(Debug, Clone)]
pub struct XlaComputation {
    proto: HloModuleProto,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation {
            proto: proto.clone(),
        }
    }

    pub fn hlo_text(&self) -> &str {
        self.proto.text()
    }
}

/// The PJRT CPU client. The stub accepts compilations (shape bookkeeping
/// works end to end) but cannot execute them.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    pub fn platform_name(&self) -> String {
        "stub-cpu".to_string()
    }

    pub fn device_count(&self) -> usize {
        1
    }

    pub fn compile(&self, comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Ok(PjRtLoadedExecutable {
            hlo_text: comp.hlo_text().to_string(),
        })
    }
}

/// A compiled executable handle. `execute` fails in the stub — swap in the
/// real bindings to run artifacts.
pub struct PjRtLoadedExecutable {
    hlo_text: String,
}

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(err(format!(
            "the offline `xla` stub cannot execute HLO ({} bytes of module text); \
             rerun with `--backend native` (or GAS_BACKEND=native) to use the \
             pure-Rust interpreter, or build against the real xla/PJRT bindings \
             to run compiled artifacts",
            self.hlo_text.len()
        )))
    }
}

/// A device buffer returned by `execute`.
pub struct PjRtBuffer {
    lit: Literal,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.lit.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrips_f32() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        assert_eq!(l.element_count(), 4);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3]).is_err());
    }

    #[test]
    fn literal_dtype_checked() {
        let l = Literal::vec1(&[1i32, 2]);
        assert!(l.to_vec::<f32>().is_err());
        assert_eq!(l.to_vec::<i32>().unwrap(), vec![1, 2]);
    }

    #[test]
    fn untyped_creation_checks_byte_count() {
        let bytes = [0u8; 8];
        assert!(Literal::create_from_shape_and_untyped_data(ElementType::F32, &[2], &bytes).is_ok());
        assert!(Literal::create_from_shape_and_untyped_data(ElementType::F32, &[3], &bytes).is_err());
    }

    #[test]
    fn tuple_decomposes() {
        let t = Literal::tuple(vec![Literal::scalar(1.5), Literal::vec1(&[2i32])]);
        assert_eq!(t.element_count(), 2);
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[0].to_vec::<f32>().unwrap(), vec![1.5]);
        assert!(Literal::scalar(0.0).to_tuple().is_err());
    }

    #[test]
    fn client_compiles_but_does_not_execute() {
        let client = PjRtClient::cpu().unwrap();
        assert_eq!(client.device_count(), 1);
        let comp = XlaComputation::from_proto(&HloModuleProto::from_text("HloModule m"));
        let exe = client.compile(&comp).unwrap();
        let args: Vec<Literal> = vec![Literal::scalar(1.0)];
        assert!(exe.execute::<Literal>(&args).is_err());
    }

    #[test]
    fn missing_hlo_file_is_an_error() {
        assert!(HloModuleProto::from_text_file("/nonexistent/x.hlo.txt").is_err());
    }
}
