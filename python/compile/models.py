"""L2: GNN operators + GAS history-injected networks, fwd/bwd in JAX.

Two program families per operator (see DESIGN.md §2):

* ``gas``  — the GAS computation: each layer computes embeddings for the
  NB in-batch nodes only; message sources are the concatenation of the
  freshly computed in-batch embeddings and the *historical* embeddings of
  the NH halo nodes (an input — gradients do not flow into histories,
  exactly Equation (2) of the paper). Per-layer in-batch embeddings are
  returned so the coordinator can push them to the history store.

* ``full`` — the exact computation on a (sub)graph: every node's embedding
  is computed at every layer. Used for full-batch training, Cluster-GCN
  (intra-cluster subgraph), and GraphSAGE-style sampled subgraphs.

All neighborhood aggregations go through the L1 Pallas kernels
(`kernels.aggregate`), so the kernels lower into the same HLO module.

Operators follow the paper's appendix §10 formulas: GCN, GAT, APPNP,
GCNII, GIN, PNA. The Lipschitz auxiliary loss (Eq. 3) is computed for
layers with H-dimensional inputs and weighted by the runtime scalar
``reg_lambda`` (0 disables).
"""

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from .kernels import aggregate as K

Params = Dict[str, jnp.ndarray]


# ---------------------------------------------------------------------------
# parameter specs (shape + init), consumed by aot.py for the manifest and by
# the Rust coordinator for initialization. init: "glorot" | "zeros" | "const:v"
# ---------------------------------------------------------------------------

def glorot(shape):
    return {"shape": list(shape), "init": "glorot"}


def zeros(shape):
    return {"shape": list(shape), "init": "zeros"}


def const(shape, v):
    return {"shape": list(shape), "init": f"const:{v}"}


def param_specs(cfg) -> List[Tuple[str, dict]]:
    """Ordered parameter list for a model config."""
    f, h, c, L = cfg.f, cfg.h, cfg.c, cfg.layers
    m = cfg.model
    specs: List[Tuple[str, dict]] = []
    if m == "gcn":
        dims = [f] + [h] * (L - 1) + [c]
        for l in range(L):
            specs.append((f"w{l}", glorot((dims[l], dims[l + 1]))))
            specs.append((f"b{l}", zeros((dims[l + 1],))))
    elif m == "gin":
        dims = [f] + [h] * L
        for l in range(L):
            specs.append((f"mlp{l}_w1", glorot((dims[l], h))))
            specs.append((f"mlp{l}_b1", zeros((h,))))
            specs.append((f"mlp{l}_w2", glorot((h, h))))
            specs.append((f"mlp{l}_b2", zeros((h,))))
            specs.append((f"eps{l}", zeros((1,))))
        specs.append(("head_w", glorot((h, c))))
        specs.append(("head_b", zeros((c,))))
    elif m == "gat":
        kh = cfg.heads
        dims = [f] + [h] * (L - 1) + [c]
        for l in range(L):
            heads_l = kh if l < L - 1 else 1
            dh = dims[l + 1] // heads_l if l < L - 1 else dims[l + 1]
            specs.append((f"w{l}", glorot((dims[l], heads_l * dh))))
            specs.append((f"asrc{l}", glorot((heads_l, dh))))
            specs.append((f"adst{l}", glorot((heads_l, dh))))
            specs.append((f"b{l}", zeros((heads_l * dh,))))
    elif m == "appnp":
        specs.append(("mlp_w1", glorot((f, h))))
        specs.append(("mlp_b1", zeros((h,))))
        specs.append(("mlp_w2", glorot((h, c))))
        specs.append(("mlp_b2", zeros((c,))))
    elif m == "gcnii":
        specs.append(("w_in", glorot((f, h))))
        specs.append(("b_in", zeros((h,))))
        specs.append(("w_stack", glorot((L, h, h))))
        specs.append(("w_out", glorot((h, c))))
        specs.append(("b_out", zeros((c,))))
    elif m == "pna":
        dims = [f] + [h] * L
        for l in range(L):
            specs.append((f"w1_{l}", glorot((2 * dims[l], h))))
            specs.append((f"w2_{l}", glorot((dims[l] + 9 * h, h))))
            specs.append((f"b2_{l}", zeros((h,))))
        specs.append(("head_w", glorot((h, c))))
        specs.append(("head_b", zeros((c,))))
    else:
        raise ValueError(f"unknown model {m}")
    return specs


# ---------------------------------------------------------------------------
# layer primitives
# ---------------------------------------------------------------------------

def _gcn_propagate(z, src, dst, w, deg, n_out, block):
    """Symmetric-normalized propagation incl. self loop: P̂ z.

    ``w`` carries 1/sqrt((deg_s+1)(deg_d+1)) for real edges, 0 for padding.
    The self term uses 1/(deg_v+1).
    """
    agg = K.scatter_sum(z, src, dst, w, n_out, block=block)
    self_w = 1.0 / (deg[:n_out] + 1.0)
    return agg + self_w[:, None] * z[:n_out]


def _leaky(x):
    return jax.nn.leaky_relu(x, negative_slope=0.2)


def gat_layer(p, l, h_src, src, dst, emask, deg, n_out, heads, block):
    """Multi-head GAT layer (appendix formula), softmax over N(v) ∪ {v}."""
    w = p[f"w{l}"]
    dh = p[f"asrc{l}"].shape[1]
    z = h_src @ w  # [NT, K*dh]
    zk = z.reshape(z.shape[0], heads, dh)
    s_src = jnp.einsum("nkd,kd->nk", zk, p[f"asrc{l}"])  # [NT, K]
    s_dst = jnp.einsum("nkd,kd->nk", zk[:n_out], p[f"adst{l}"])  # [n_out, K]
    e = _leaky(s_src[src] + s_dst[dst])  # [E, K]
    e_self = _leaky(s_src[:n_out] + s_dst)  # [n_out, K]

    eidx = jnp.arange(src.shape[0], dtype=src.dtype)
    neg = jnp.asarray(-1.0e30, e.dtype)
    e_m = jnp.where(emask[:, None] > 0, e, neg)
    mx = K.scatter_max(e_m, eidx, dst, emask, n_out, block=block)  # [n_out,K]
    # softmax is shift-invariant: the max is for numerical stability only.
    mx = jax.lax.stop_gradient(jnp.maximum(mx, e_self))
    ex = jnp.where(emask[:, None] > 0, jnp.exp(e_m - mx[dst]), 0.0)  # [E,K]
    ex_self = jnp.exp(e_self - mx)
    denom = K.scatter_sum(ex, eidx, dst, jnp.ones_like(emask), n_out,
                          block=block) + ex_self
    alpha = ex / jnp.maximum(denom[dst], 1e-16)  # [E, K]
    msgs = (alpha[:, :, None] * zk[src]).reshape(src.shape[0], heads * dh)
    out = K.scatter_sum(msgs, eidx, dst, jnp.ones_like(emask), n_out,
                        block=block)
    self_msg = (ex_self / jnp.maximum(denom, 1e-16))[:, :, None] * zk[:n_out]
    out = out + self_msg.reshape(n_out, heads * dh)
    return out + p[f"b{l}"]


def gin_layer(p, l, h_src, h_self, src, dst, w, n_out, block):
    """GIN: MLP((1+eps) h_v + sum_{w in N(v)} h_w)."""
    agg = K.scatter_sum(h_src, src, dst, w, n_out, block=block)
    pre = (1.0 + p[f"eps{l}"][0]) * h_self + agg
    z = jax.nn.relu(pre @ p[f"mlp{l}_w1"] + p[f"mlp{l}_b1"])
    return z @ p[f"mlp{l}_w2"] + p[f"mlp{l}_b2"]


def pna_layer(p, l, h_src, h_self, src, dst, w, deg, scaler_mean, n_out,
              block):
    """PNA: 3 aggregators x 3 degree scalers, tensor product (appendix)."""
    eidx = jnp.arange(src.shape[0], dtype=src.dtype)
    # fused pair-MLP sum (hot path: avoids [E, 2H] in HBM)
    s = K.scatter_pair_mlp_sum(h_src, h_self, src, dst, w, p[f"w1_{l}"],
                               n_out, block=block)
    # materialized per-edge messages for min/max
    pair = jnp.concatenate([h_self[dst], h_src[src]], axis=1)
    msgs = pair @ p[f"w1_{l}"]  # [E, h]
    mx = K.scatter_max(msgs, eidx, dst, w, n_out, block=block)
    mn = K.scatter_min(msgs, eidx, dst, w, n_out, block=block)
    d = jnp.maximum(deg[:n_out], 1.0)
    mean = s / d[:, None]
    aggs = jnp.concatenate([mean, mn, mx], axis=1)  # [n_out, 3h]
    logd = jnp.log(deg[:n_out] + 1.0)
    amp = (logd / scaler_mean)[:, None]
    att = (scaler_mean / jnp.maximum(logd, 1e-6))[:, None]
    scaled = jnp.concatenate([aggs, aggs * amp, aggs * att], axis=1)  # 9h
    out = jnp.concatenate([h_self, scaled], axis=1) @ p[f"w2_{l}"]
    return out + p[f"b2_{l}"]


# ---------------------------------------------------------------------------
# networks. Shared calling convention, cfg from configs.ArtifactConfig.
#
# GAS inputs:  x[NT,F] hist[(L-1),NH,Hh] + edge/meta tensors
# FULL inputs: x[NB,F]                   + edge/meta tensors (no hist)
# returns (logits[n_out,C], push[(L-1),NB,Hh] or zeros, reg scalar)
# ---------------------------------------------------------------------------

def _sources(h_batch, hist_l, full):
    """Message sources for the next layer: in-batch ++ halo-history."""
    if full:
        return h_batch
    return jnp.concatenate([h_batch, hist_l], axis=0)


def run_gcn(p, cfg, x, src, dst, w, hist, deg, noise, full):
    L = cfg.layers
    n_out = x.shape[0] if full else cfg.nb
    h_src = x
    push = []
    reg = 0.0
    for l in range(L):
        z = h_src @ p[f"w{l}"]
        h = _gcn_propagate(z, src, dst, w, deg, n_out if full else cfg.nb,
                           cfg.block) + p[f"b{l}"]
        if l < L - 1:
            h = jax.nn.relu(h)
            push.append(h if not full else h[: cfg.nb])
            h_src = h if full else _sources(h, hist[l], full)
    logits = h
    return logits, _stack_push(push, cfg), reg


def run_gat(p, cfg, x, src, dst, w, hist, deg, noise, full):
    L = cfg.layers
    n_out = x.shape[0] if full else cfg.nb
    emask = jnp.where(w > 0, 1.0, 0.0)
    h_src = x
    push = []
    reg = 0.0
    for l in range(L):
        heads = cfg.heads if l < L - 1 else 1
        h = gat_layer(p, l, h_src, src, dst, emask, deg, n_out, heads,
                      cfg.block)
        if l < L - 1:
            h = jax.nn.elu(h)
            push.append(h if not full else h[: cfg.nb])
            h_src = h if full else _sources(h, hist[l], full)
    return h, _stack_push(push, cfg), reg


def run_appnp(p, cfg, x, src, dst, w, hist, deg, noise, full):
    """Predict (MLP) then propagate with teleport alpha. hist dim = C."""
    L = cfg.layers  # number of propagation steps
    n_out = x.shape[0] if full else cfg.nb
    z = jax.nn.relu(x @ p["mlp_w1"] + p["mlp_b1"])
    h0 = z @ p["mlp_w2"] + p["mlp_b2"]  # [NT or NB, C] exact everywhere
    h = h0
    push = []
    alpha = cfg.alpha
    for l in range(L):
        srcs = h if full else (h0 if l == 0 else _sources(h, hist[l - 1], full))
        # layer-0 sources are exact h0 rows for the halo too (no staleness).
        if not full and l == 0:
            srcs = h0
            h = h0[: cfg.nb]
        prop = _gcn_propagate(srcs, src, dst, w, deg, n_out, cfg.block)
        h = (1.0 - alpha) * prop + alpha * h0[: n_out]
        if l < L - 1:
            push.append(h if not full else h[: cfg.nb])
    return h, _stack_push(push, cfg), 0.0


def run_gcnii(p, cfg, x, src, dst, w, hist, deg, noise, full):
    """GCNII with a scan over the stacked per-layer weights."""
    L = cfg.layers
    n_out = x.shape[0] if full else cfg.nb
    alpha = cfg.alpha
    betas = jnp.log(cfg.lam / jnp.arange(1, L + 1) + 1.0).astype(x.dtype)
    h0 = jax.nn.relu(x @ p["w_in"] + p["b_in"])  # [NT or NB, H] exact
    reg_on = cfg.with_reg

    if full:
        def step(h, lw):
            wl, beta = lw
            prop = _gcn_propagate(h, src, dst, w, deg, n_out, cfg.block)
            hn = (1.0 - alpha) * prop + alpha * h0
            out = jax.nn.relu((1.0 - beta) * hn + beta * (hn @ wl))
            return out, h  # emit previous (so ys = h_0..h_{L-1})
        h, ys = jax.lax.scan(step, h0, (p["w_stack"], betas))
        push = ys[1:]  # h_1..h_{L-1} for batch nodes
        logits = h @ p["w_out"] + p["b_out"]
        return logits, push[:, : cfg.nb, :], 0.0

    # GAS: halo sources layer 1 are exact h0 rows; layers 2..L use history.
    hist_ext = jnp.concatenate([h0[cfg.nb:][None], hist], axis=0)  # [L,NH,H]
    h0b = h0[: cfg.nb]

    def step(carry, lw):
        h, regacc = carry
        wl, beta, hist_l = lw
        srcs = jnp.concatenate([h, hist_l], axis=0)

        def f(s):
            prop = _gcn_propagate(s, src, dst, w, deg, cfg.nb, cfg.block)
            hn = (1.0 - alpha) * prop + alpha * h0b
            return jax.nn.relu((1.0 - beta) * hn + beta * (hn @ wl))

        out = f(srcs)
        if reg_on:
            out_p = f(srcs + noise[: srcs.shape[0], : srcs.shape[1]])
            regacc = regacc + jnp.mean(jnp.sum((out - out_p) ** 2, axis=-1))
        return (out, regacc), out

    (h, reg), ys = jax.lax.scan(step, (h0b, 0.0),
                                (p["w_stack"], betas, hist_ext))
    push = ys[:-1]  # h_1..h_{L-1}
    logits = h @ p["w_out"] + p["b_out"]
    return logits, push, reg


def run_gin(p, cfg, x, src, dst, w, hist, deg, noise, full):
    L = cfg.layers
    n_out = x.shape[0] if full else cfg.nb
    h_src = x
    push = []
    reg = 0.0
    for l in range(L):
        h_self = h_src[: n_out]
        h = gin_layer(p, l, h_src, h_self, src, dst, w, n_out, cfg.block)
        if cfg.with_reg and l > 0:  # inputs are H-dim from layer 1 on
            def f(s, _l=l, _hs_shape=h_src.shape):
                hs = s
                return gin_layer(p, _l, hs, hs[: n_out], src, dst, w, n_out,
                                 cfg.block)
            hp = h_src + noise[: h_src.shape[0], : h_src.shape[1]]
            h_pert = f(hp)
            reg = reg + jnp.mean(jnp.sum((h - h_pert) ** 2, axis=-1))
        h = jax.nn.relu(h)
        if l < L - 1:
            push.append(h if not full else h[: cfg.nb])
            h_src = h if full else _sources(h, hist[l], full)
    logits = h @ p["head_w"] + p["head_b"]
    return logits, _stack_push(push, cfg), reg


def run_pna(p, cfg, x, src, dst, w, hist, deg, noise, full):
    L = cfg.layers
    n_out = x.shape[0] if full else cfg.nb
    h_src = x
    push = []
    reg = 0.0
    for l in range(L):
        h_self = h_src[: n_out]
        h = pna_layer(p, l, h_src, h_self, src, dst, w, deg, cfg.scaler_mean,
                      n_out, cfg.block)
        h = jax.nn.relu(h)
        if l < L - 1:
            push.append(h if not full else h[: cfg.nb])
            h_src = h if full else _sources(h, hist[l], full)
    logits = h @ p["head_w"] + p["head_b"]
    return logits, _stack_push(push, cfg), reg


def _stack_push(push, cfg):
    if not push:
        return jnp.zeros((0, cfg.nb, cfg.hist_dim), jnp.float32)
    return jnp.stack(push, axis=0)


RUNNERS = {
    "gcn": run_gcn,
    "gat": run_gat,
    "appnp": run_appnp,
    "gcnii": run_gcnii,
    "gin": run_gin,
    "pna": run_pna,
}


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------

def softmax_ce(logits, labels, mask):
    """Masked mean cross-entropy; labels i32 [N], mask f32 [N]."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None].astype(jnp.int32),
                               axis=1)[:, 0]
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def bce_multilabel(logits, labels, mask):
    """Masked mean binary CE; labels f32 [N,C], mask f32 [N]."""
    logp = jax.nn.log_sigmoid(logits)
    lognp = jax.nn.log_sigmoid(-logits)
    per = -(labels * logp + (1.0 - labels) * lognp).mean(axis=-1)
    return jnp.sum(per * mask) / jnp.maximum(jnp.sum(mask), 1.0)


# ---------------------------------------------------------------------------
# train step (value_and_grad) — the artifact entry point
# ---------------------------------------------------------------------------

def make_train_step(cfg):
    """Returns fn(params..., inputs...) -> (loss, grads..., push, logits)."""
    runner = RUNNERS[cfg.model]
    full = cfg.program == "full"

    def loss_fn(p, x, src, dst, w, hist, labels, label_mask, deg, noise,
                reg_lambda):
        logits, push, reg = runner(p, cfg, x, src, dst, w, hist, deg, noise,
                                   full)
        lg = logits[: cfg.nb]
        if cfg.loss == "ce":
            task = softmax_ce(lg, labels, label_mask)
        else:
            task = bce_multilabel(lg, labels, label_mask)
        return task + reg_lambda * reg, (push, lg)

    def train_step(p, x, src, dst, w, hist, labels, label_mask, deg, noise,
                   reg_lambda):
        (loss, (push, logits)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(p, x, src, dst, w, hist, labels,
                                   label_mask, deg, noise, reg_lambda)
        return loss, grads, push, logits

    return train_step


def example_inputs(cfg):
    """ShapeDtypeStructs in artifact input order (params first)."""
    sd = jax.ShapeDtypeStruct
    f32, i32 = jnp.float32, jnp.int32
    nt = cfg.nb + cfg.nh
    n_in = cfg.nb if cfg.program == "full" else nt
    specs = param_specs(cfg)
    params = {k: sd(tuple(v["shape"]), f32) for k, v in specs}
    hist_layers = max(cfg.layers - 1, 0)
    noise_dim = max(cfg.hist_dim, cfg.h)
    if cfg.program == "full":
        # full programs never read histories; keep a 1-element placeholder
        # (zero-sized literals are awkward for the rust xla bindings).
        hist = sd((1, 1, 1), f32)
    else:
        hist = sd((hist_layers, cfg.nh, cfg.hist_dim), f32)
    if cfg.loss == "ce":
        labels = sd((cfg.nb,), i32)
    else:
        labels = sd((cfg.nb, cfg.c), f32)
    return (
        params,
        sd((n_in, cfg.f), f32),                       # x
        sd((cfg.e,), i32),                            # src
        sd((cfg.e,), i32),                            # dst
        sd((cfg.e,), f32),                            # w
        hist,                                         # hist
        labels,
        sd((cfg.nb,), f32),                           # label_mask
        sd((n_in,), f32),                             # deg
        sd((n_in, noise_dim), f32),                   # noise
        sd((), f32),                                  # reg_lambda
    )
