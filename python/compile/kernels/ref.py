"""Pure-jnp oracles for the Pallas kernels — the correctness ground truth.

Each function mirrors one kernel in aggregate.py with the simplest possible
jnp formulation (no blocking, no grid). pytest asserts allclose between the
two across hypothesis-driven shape/value sweeps.
"""

import jax.numpy as jnp


def scatter_sum_ref(x, src, dst, w, num_out):
    msgs = x[src, :] * w[:, None]
    return jnp.zeros((num_out, x.shape[1]), x.dtype).at[dst].add(msgs)


def scatter_max_ref(x, src, dst, mask, num_out):
    big = jnp.asarray(3.0e38, x.dtype)
    vals = jnp.where(mask[:, None] > 0, x[src, :], -big)
    out = jnp.full((num_out, x.shape[1]), -big, x.dtype).at[dst].max(vals)
    return jnp.where(out <= -1.0e38, jnp.zeros_like(out), out)


def scatter_min_ref(x, src, dst, mask, num_out):
    big = jnp.asarray(3.0e38, x.dtype)
    vals = jnp.where(mask[:, None] > 0, x[src, :], big)
    out = jnp.full((num_out, x.shape[1]), big, x.dtype).at[dst].min(vals)
    return jnp.where(out >= 1.0e38, jnp.zeros_like(out), out)


def scatter_sum_vec_ref(v, dst, num_out):
    return jnp.zeros((num_out,), v.dtype).at[dst].add(v)


def scatter_pair_mlp_sum_ref(x_src, x_dst, src, dst, w, w1, num_out):
    pair = jnp.concatenate([x_dst[dst, :], x_src[src, :]], axis=1)
    msgs = (pair @ w1) * w[:, None]
    return jnp.zeros((num_out, w1.shape[1]), x_src.dtype).at[dst].add(msgs)


def edge_softmax_parts_ref(logits, dst, mask, num_out):
    neg = jnp.asarray(-1.0e30, logits.dtype)
    masked = jnp.where(mask > 0, logits, neg)
    big = jnp.asarray(3.0e38, logits.dtype)
    mx = jnp.full((num_out,), -big, logits.dtype).at[dst].max(
        jnp.where(mask > 0, masked, -big))
    mx = jnp.where(mx <= -1.0e38, jnp.zeros_like(mx), mx)
    ex = jnp.where(mask > 0, jnp.exp(masked - mx[dst]), 0.0)
    denom = jnp.zeros((num_out,), logits.dtype).at[dst].add(ex)
    return mx, denom, ex
