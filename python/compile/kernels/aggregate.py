"""L1 Pallas kernels: sparse neighbor aggregation (the message-passing hot spot).

The paper's hot loop — for every GNN layer, aggregate messages from the
mini-batch's 1-hop sources (in-batch nodes + halo histories) into in-batch
destinations — is an edge-parallel gather -> weight -> segment-scatter-add.
On GPU the reference implementation (PyG) uses atomics over threadblocks;
the TPU adaptation (DESIGN.md §Hardware-Adaptation) tiles the *edge list*
into VMEM-sized blocks via BlockSpec and keeps the output tile resident
across the edge-block grid (revisiting-reduction pattern). `interpret=True`
everywhere: the CPU PJRT plugin cannot execute Mosaic custom-calls, so the
kernels lower to plain HLO while preserving the block structure.

Autodiff: `pallas_call` grid kernels are not JVP-traceable in this jax
version, so every public op carries a `custom_vjp` whose backward pass is
*also* expressed with the pallas scatter kernel (the VJP of a
gather->scale->scatter is another gather->scale->scatter with src/dst
swapped) — the optimized kernel stays on the hot path in both directions.

Padding convention: padded edges carry ``w == 0`` and ``src == dst == 0``
so they contribute exactly nothing (scatter_sum) or lose every max/min.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default edge-block size. VMEM estimate per block (f32):
#   src/dst idx: 2 * EB * 4B, w: EB * 4B, gathered rows: EB * H * 4B,
#   out tile resident: N_out * H * 4B.
# For EB=2048, H=64, N_out=4096: 2048*12B + 2048*64*4B + 4096*64*4B
#   = 24KB + 512KB + 1MB  << 16MB VMEM.
DEFAULT_EDGE_BLOCK = 2048
_BIG = 3.0e38


def _choose_block(num_edges: int, block: int) -> int:
    """Pick an edge-block size that divides the padded edge count."""
    block = min(block, num_edges)
    while num_edges % block != 0:
        block -= 1
    return max(block, 1)


# ---------------------------------------------------------------------------
# raw pallas implementations (not differentiable; wrapped below)
# ---------------------------------------------------------------------------

def _scatter_sum_kernel(src_ref, dst_ref, w_ref, x_ref, o_ref):
    """One edge-block: gather rows of x, weight, segment-add into out.

    Out is the *whole* [N_out, H] array (index_map pinned to 0) and is
    accumulated across grid steps — the revisiting-reduction pattern.
    """
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    src = src_ref[...]
    dst = dst_ref[...]
    w = w_ref[...]
    msgs = x_ref[src, :] * w[:, None]
    o_ref[...] += jnp.zeros_like(o_ref).at[dst].add(msgs)


def _scatter_sum_impl(x, src, dst, w, num_out, block):
    num_edges = src.shape[0]
    feat = x.shape[1]
    eb = _choose_block(num_edges, block)
    grid = (num_edges // eb,)
    return pl.pallas_call(
        _scatter_sum_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((eb,), lambda i: (i,)),
            pl.BlockSpec((eb,), lambda i: (i,)),
            pl.BlockSpec((eb,), lambda i: (i,)),
            pl.BlockSpec(x.shape, lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((num_out, feat), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((num_out, feat), x.dtype),
        interpret=True,
    )(src, dst, w, x)


def _scatter_extreme_kernel(src_ref, dst_ref, m_ref, x_ref, o_ref, *, sign):
    """Shared body for scatter_max (sign=+1) / scatter_min (sign=-1)."""
    step = pl.program_id(0)
    big = jnp.asarray(_BIG, o_ref.dtype)

    @pl.when(step == 0)
    def _init():
        o_ref[...] = jnp.full_like(o_ref, -big)

    src = src_ref[...]
    dst = dst_ref[...]
    mask = m_ref[...]
    vals = sign * x_ref[src, :]
    vals = jnp.where(mask[:, None] > 0, vals, -big)
    blk = jnp.full_like(o_ref, -big).at[dst].max(vals)
    o_ref[...] = jnp.maximum(o_ref[...], blk)


def _scatter_extreme_impl(x, src, dst, mask, num_out, sign, block):
    num_edges = src.shape[0]
    feat = x.shape[1]
    eb = _choose_block(num_edges, block)
    grid = (num_edges // eb,)
    out = pl.pallas_call(
        partial(_scatter_extreme_kernel, sign=sign),
        grid=grid,
        in_specs=[
            pl.BlockSpec((eb,), lambda i: (i,)),
            pl.BlockSpec((eb,), lambda i: (i,)),
            pl.BlockSpec((eb,), lambda i: (i,)),
            pl.BlockSpec(x.shape, lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((num_out, feat), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((num_out, feat), x.dtype),
        interpret=True,
    )(src, dst, mask, x)
    # Destinations with no live in-edges come out as -BIG; clamp to 0 so
    # isolated (or fully padded) nodes aggregate to zero like PyG does.
    out = jnp.where(out <= -1.0e38, jnp.zeros_like(out), out)
    return sign * out


def _scatter_sum_vec_kernel(dst_ref, v_ref, o_ref):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.zeros_like(o_ref).at[dst_ref[...]].add(v_ref[...])


def _scatter_sum_vec_impl(v, dst, num_out, block):
    num_edges = dst.shape[0]
    eb = _choose_block(num_edges, block)
    grid = (num_edges // eb,)
    return pl.pallas_call(
        _scatter_sum_vec_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((eb,), lambda i: (i,)),
            pl.BlockSpec((eb,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((num_out,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((num_out,), v.dtype),
        interpret=True,
    )(dst, v)


def _scatter_pair_kernel(src_ref, dst_ref, w_ref, xs_ref, xd_ref, w1_ref,
                         o_ref):
    """Fused PNA-style edge MLP + scatter: per edge, [x_dst || x_src] @ W1."""
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    src = src_ref[...]
    dst = dst_ref[...]
    w = w_ref[...]
    pair = jnp.concatenate([xd_ref[dst, :], xs_ref[src, :]], axis=1)
    msgs = (pair @ w1_ref[...]) * w[:, None]
    o_ref[...] += jnp.zeros_like(o_ref).at[dst].add(msgs)


def _scatter_pair_impl(x_src, x_dst, src, dst, w, w1, num_out, block):
    num_edges = src.shape[0]
    eb = _choose_block(num_edges, block)
    grid = (num_edges // eb,)
    h_out = w1.shape[1]
    return pl.pallas_call(
        _scatter_pair_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((eb,), lambda i: (i,)),
            pl.BlockSpec((eb,), lambda i: (i,)),
            pl.BlockSpec((eb,), lambda i: (i,)),
            pl.BlockSpec(x_src.shape, lambda i: (0, 0)),
            pl.BlockSpec(x_dst.shape, lambda i: (0, 0)),
            pl.BlockSpec(w1.shape, lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((num_out, h_out), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((num_out, h_out), x_src.dtype),
        interpret=True,
    )(src, dst, w, x_src, x_dst, w1)


# ---------------------------------------------------------------------------
# public differentiable ops
#
# All are module-level custom_vjp functions taking index arrays as explicit
# arguments (returning None cotangents) — closures over tracers break inside
# lax.scan (e.g. the GCNII layer stack).
# ---------------------------------------------------------------------------

@partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _scatter_sum_cv(num_out, block, x, src, dst, w):
    return _scatter_sum_impl(x, src, dst, w, num_out, block)


def _scatter_sum_fwd(num_out, block, x, src, dst, w):
    return _scatter_sum_cv(num_out, block, x, src, dst, w), (x, src, dst, w)


def _scatter_sum_bwd(num_out, block, res, g):
    x, src, dst, w = res
    # VJP of gather->scale->scatter is gather->scale->scatter, src/dst swapped
    dx = _scatter_sum_impl(g, dst, src, w, x.shape[0], block)
    dw = jnp.sum(x[src] * g[dst], axis=1)
    return dx, None, None, dw


_scatter_sum_cv.defvjp(_scatter_sum_fwd, _scatter_sum_bwd)


def scatter_sum(x, src, dst, w, num_out, *, block=DEFAULT_EDGE_BLOCK):
    """out[d] = sum_e [dst_e == d] * w_e * x[src_e]  with out: [num_out, H].

    x: [N_src, H] f32 — message sources (in-batch embeddings ++ halo history)
    src: [E] i32 into x, dst: [E] i32 into out, w: [E] f32 (0 => padded).
    """
    return _scatter_sum_cv(num_out, block, x, src, dst, w)


@partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2))
def _scatter_ext_cv(num_out, block, sign, x, src, dst, mask):
    return _scatter_extreme_impl(x, src, dst, mask, num_out, sign, block)


def _scatter_ext_fwd(num_out, block, sign, x, src, dst, mask):
    out = _scatter_ext_cv(num_out, block, sign, x, src, dst, mask)
    return out, (x, out, src, dst, mask)


def _scatter_ext_bwd(num_out, block, sign, res, g):
    x, out, src, dst, mask = res
    # subgradient: route g to edges attaining the extreme (ties share).
    eidx = jnp.arange(src.shape[0], dtype=src.dtype)
    eq = (x[src] == out[dst]).astype(g.dtype)
    vals = g[dst] * eq
    dx = _scatter_sum_impl(vals, eidx, src, mask, x.shape[0], block)
    return dx, None, None, None


_scatter_ext_cv.defvjp(_scatter_ext_fwd, _scatter_ext_bwd)


def scatter_max(x, src, dst, mask, num_out, *, block=DEFAULT_EDGE_BLOCK):
    """out[d] = max_e {x[src_e] : dst_e == d, mask_e > 0}; 0 if no edge."""
    return _scatter_ext_cv(num_out, block, 1.0, x, src, dst, mask)


def scatter_min(x, src, dst, mask, num_out, *, block=DEFAULT_EDGE_BLOCK):
    """out[d] = min_e {x[src_e] : dst_e == d, mask_e > 0}; 0 if no edge."""
    return _scatter_ext_cv(num_out, block, -1.0, x, src, dst, mask)


@partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _scatter_vec_cv(num_out, block, v, dst):
    return _scatter_sum_vec_impl(v, dst, num_out, block)


def _scatter_vec_fwd(num_out, block, v, dst):
    return _scatter_vec_cv(num_out, block, v, dst), dst


def _scatter_vec_bwd(num_out, block, dst, g):
    return g[dst], None


_scatter_vec_cv.defvjp(_scatter_vec_fwd, _scatter_vec_bwd)


def scatter_sum_vec(v, dst, num_out, *, block=DEFAULT_EDGE_BLOCK):
    """Scalar-per-edge scatter-add: out[d] = sum_e [dst_e==d] v_e."""
    return _scatter_vec_cv(num_out, block, v, dst)


@partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _scatter_pair_cv(num_out, block, x_src, x_dst, src, dst, w, w1):
    return _scatter_pair_impl(x_src, x_dst, src, dst, w, w1, num_out, block)


def _scatter_pair_fwd(num_out, block, x_src, x_dst, src, dst, w, w1):
    out = _scatter_pair_cv(num_out, block, x_src, x_dst, src, dst, w, w1)
    return out, (x_src, x_dst, src, dst, w, w1)


def _scatter_pair_bwd(num_out, block, res, g):
    xs, xd, src, dst, w, w1 = res
    hd = xd.shape[1]
    eidx = jnp.arange(src.shape[0], dtype=src.dtype)
    dmsgs = g[dst] * w[:, None]              # [E, H']
    dpair = dmsgs @ w1.T                     # [E, hd + hs]
    dxd = _scatter_sum_impl(dpair[:, :hd], eidx, dst, jnp.ones_like(w),
                            xd.shape[0], block)
    dxs = _scatter_sum_impl(dpair[:, hd:], eidx, src, jnp.ones_like(w),
                            xs.shape[0], block)
    pair = jnp.concatenate([xd[dst], xs[src]], axis=1)
    dw1 = pair.T @ dmsgs
    dw = jnp.sum((pair @ w1) * g[dst], axis=1)
    return dxs, dxd, None, None, dw, dw1


_scatter_pair_cv.defvjp(_scatter_pair_fwd, _scatter_pair_bwd)


def scatter_pair_mlp_sum(x_src, x_dst, src, dst, w, w1, num_out,
                         *, block=DEFAULT_EDGE_BLOCK):
    """Fused edge-message transform + aggregation (PNA hot path).

    out[d] = sum_e [dst_e==d] w_e * ( [x_dst[dst_e] || x_src[src_e]] @ w1 )
    Fusing the pair-concat matmul into the edge block avoids materializing
    the [E, 2H] pair tensor in HBM — the classic PNA memory blow-up.
    """
    return _scatter_pair_cv(num_out, block, x_src, x_dst, src, dst, w, w1)


def edge_softmax_parts(logits, dst, mask, num_out, *, block=DEFAULT_EDGE_BLOCK):
    """Return (per-dst max, per-dst sum of exp, per-edge exp) for edge-softmax.

    The caller computes alpha_e = ex_e / denom[dst_e]. The max is
    stop-gradiented (softmax is shift-invariant, so this is exact).
    """
    num_edges = dst.shape[0]
    eidx = jnp.arange(num_edges, dtype=dst.dtype)
    neg = jnp.asarray(-1.0e30, logits.dtype)
    masked = jnp.where(mask > 0, logits, neg)
    mx = jax.lax.stop_gradient(
        scatter_max(masked[:, None], eidx, dst, mask, num_out,
                    block=block)[:, 0])
    ex = jnp.where(mask > 0, jnp.exp(masked - mx[dst]), 0.0)
    denom = scatter_sum_vec(ex, dst, num_out, block=block)
    return mx, denom, ex
