"""Artifact + dataset-profile registry — the single source of truth.

Every compiled artifact is described by an `ArtifactConfig` (static shapes,
model family, program family). Every synthetic dataset is described by a
`DatasetProfile` mirroring the statistics of the paper's Table 8 (scaled to
the CPU testbed; scale factors recorded in DESIGN.md §3 and EXPERIMENTS.md).
`aot.py` lowers all artifacts and writes everything — including the dataset
profiles — into artifacts/manifest.json, which the Rust coordinator treats
as its configuration root. Rust never re-derives shapes on its own.
"""

import math
from dataclasses import dataclass, field, asdict
from typing import Dict, List, Optional


# ---------------------------------------------------------------------------
# dataset profiles (synthetic stand-ins for the paper's datasets, §3 DESIGN)
# ---------------------------------------------------------------------------

@dataclass
class DatasetProfile:
    name: str
    kind: str          # "planted" (homophilic planted partition) | "sbm"
    n: int             # nodes (scaled)
    f: int             # feature dim
    c: int             # classes
    avg_deg: float     # mean *undirected* degree (directed deg ~ same)
    multilabel: bool = False
    train_frac: float = 0.1
    val_frac: float = 0.15
    homophily: float = 0.8    # fraction of intra-class edges (planted)
    feat_noise: float = 1.0   # class-center feature SNR control
    parts: int = 4            # METIS partitions (=> mini-batches)
    paper_n: int = 0          # the paper's original node count
    seed: int = 7


def _p(name, kind, n, f, c, deg, parts, paper_n, train_frac=0.1,
       multilabel=False, homophily=0.8, seed=7):
    return DatasetProfile(
        name=name, kind=kind, n=n, f=f, c=c, avg_deg=deg, parts=parts,
        paper_n=paper_n, train_frac=train_frac, multilabel=multilabel,
        homophily=homophily, seed=seed)


# Small transductive benchmarks (Table 1 / 2 / 6) — near-original scale,
# feature dims trimmed for the CPU testbed.
SMALL = [
    _p("cora",             "planted", 2708, 256, 7,  3.9, 4,  2708,  0.052),
    _p("citeseer",         "planted", 3327, 256, 6,  2.8, 4,  3327,  0.036),
    _p("pubmed",           "planted", 6000, 128, 3,  4.5, 6,  19717, 0.02),
    _p("coauthor_cs",      "planted", 6000, 256, 15, 8.9, 8,  18333, 0.016),
    _p("coauthor_physics", "planted", 6000, 128, 5, 12.0, 8,  34493, 0.01),
    _p("amazon_computer",  "planted", 6000, 128, 10, 16.0, 8, 13752, 0.015),
    _p("amazon_photo",     "planted", 5000, 128, 8, 16.0, 8,  7650,  0.021),
    _p("wiki_cs",          "planted", 4000, 128, 10, 14.0, 8, 11701, 0.05),
]

# Large benchmarks (Table 3 / 4 / 5 / 6) — scaled down, structure preserved.
LARGE = [
    _p("cluster",  "sbm",     24000, 6,   6,  12.0, 32, 1406436, 0.8335),
    _p("reddit",   "planted", 40000, 128, 41, 24.0, 40, 232965,  0.65),
    _p("ppi",      "planted", 12000, 64,  40, 14.0, 20, 56944,   0.75,
       multilabel=True),
    _p("flickr",   "planted", 20000, 128, 7,  10.0, 24, 89250,   0.50),
    _p("yelp",     "planted", 40000, 64,  50, 10.0, 40, 716847,  0.70,
       multilabel=True),
    _p("arxiv",    "planted", 30000, 128, 40, 7.0,  32, 169343,  0.54),
    _p("products", "planted", 120000, 100, 47, 15.0, 96, 2449029, 0.08),
]

PROFILES: Dict[str, DatasetProfile] = {p.name: p for p in SMALL + LARGE}


# ---------------------------------------------------------------------------
# artifact configs
# ---------------------------------------------------------------------------

@dataclass
class ArtifactConfig:
    name: str
    model: str         # gcn | gat | appnp | gcnii | gin | pna
    program: str       # "gas" | "full"
    dataset: str       # profile name ("" for synthetic fig4 configs)
    nb: int            # padded in-batch nodes (== padded total for "full")
    nh: int            # padded halo nodes (0 for "full")
    e: int             # padded directed edge count
    f: int
    h: int
    c: int
    layers: int
    loss: str = "ce"   # "ce" | "bce"
    heads: int = 4     # GAT
    alpha: float = 0.1     # APPNP/GCNII teleport
    lam: float = 1.0       # GCNII beta = log(lam/l + 1)
    with_reg: bool = False  # compile the Lipschitz-reg branch (GIN/GCNII)
    edge_weight: str = "gcn_norm"  # "gcn_norm" | "ones" (rust-side w calc)
    scaler_mean: float = 1.0       # PNA: mean log(deg+1), baked
    block: int = 2048              # L1 edge-block size
    hist_dim: int = 0              # set in __post_init__

    def __post_init__(self):
        if self.hist_dim == 0:
            self.hist_dim = self.c if self.model == "appnp" else self.h

    @property
    def nt(self) -> int:
        return self.nb + self.nh


MODEL_EDGE_WEIGHT = {
    "gcn": "gcn_norm", "gcnii": "gcn_norm", "appnp": "gcn_norm",
    "gat": "ones", "gin": "ones", "pna": "ones",
}

# layers per model family for the standard benchmarks
MODEL_LAYERS = {"gcn": 2, "gat": 2, "appnp": 10, "gcnii": 8, "gin": 4,
                "pna": 3}


def _gas_shapes(p: DatasetProfile):
    """Padded GAS batch shapes for a profile: one METIS part per batch."""
    nb = int(math.ceil(p.n / p.parts * 1.5))
    nh = min(p.n, 8 * nb)
    # edges with dst in batch: ~deg * nb, inflated for random-batch ablations
    e = _round_up(int(p.avg_deg * nb * 3.0) + 64, 256)
    return nb, nh, e


def _full_shapes(p: DatasetProfile):
    nb = p.n
    e = _round_up(int(p.n * p.avg_deg * 1.10) + 64, 256)
    return nb, 0, e


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def make_config(dataset: str, model: str, program: str, *, layers=None,
                h=64, with_reg=False, suffix="", heads=4) -> ArtifactConfig:
    p = PROFILES[dataset]
    layers = layers or MODEL_LAYERS[model]
    if program == "gas":
        nb, nh, e = _gas_shapes(p)
    else:
        nb, nh, e = _full_shapes(p)
    loss = "bce" if p.multilabel else "ce"
    name = f"{dataset}_{model}{layers}_{program}{suffix}"
    return ArtifactConfig(
        name=name, model=model, program=program, dataset=dataset,
        nb=nb, nh=nh, e=e, f=p.f, h=h, c=p.c, layers=layers, loss=loss,
        heads=heads, with_reg=with_reg,
        edge_weight=MODEL_EDGE_WEIGHT[model],
        scaler_mean=math.log(p.avg_deg + 1.0),
    )


def build_registry() -> List[ArtifactConfig]:
    cfgs: List[ArtifactConfig] = []

    # --- Table 1 / Table 2: 4 models x 8 small datasets x {full, gas} ------
    for p in SMALL:
        for model in ["gcn", "gat", "appnp", "gcnii"]:
            reg = model == "gcnii"  # Table 2 ablation toggles reg_lambda
            cfgs.append(make_config(p.name, model, "gas", with_reg=reg))
            cfgs.append(make_config(p.name, model, "full"))

    # --- Fig. 3: deep GCNII-64 and expressive GIN-4 ------------------------
    cfgs.append(make_config("cora", "gcnii", "gas", layers=64,
                            with_reg=True, suffix="_deep"))
    cfgs.append(make_config("cora", "gcnii", "full", layers=64,
                            suffix="_deep"))
    cfgs.append(make_config("cluster", "gin", "gas", with_reg=True))
    cfgs.append(make_config("cluster", "gin", "full"))

    # --- Table 4: 4-layer GCN (GTTF comparison) ----------------------------
    for ds in ["cora", "pubmed", "ppi", "flickr"]:
        cfgs.append(make_config(ds, "gcn", "gas", layers=4))
        cfgs.append(make_config(ds, "gcn", "full", layers=4))

    # --- Table 3 / 5: large datasets via GAS -------------------------------
    # (gat/appnp joined once the native interpreter grew them, so the
    # large-graph tables report the attention/teleport rows too)
    for p in LARGE:
        if p.name == "cluster":
            continue
        for model in ["gcn", "gat", "appnp", "gcnii", "pna"]:
            reg = model == "gcnii"
            cfgs.append(make_config(p.name, model, "gas", with_reg=reg))
    # full-batch feasible on the two smaller large graphs (Table 5 rows)
    for ds in ["flickr", "arxiv"]:
        for model in ["gcn", "gat", "appnp", "gcnii", "pna"]:
            cfgs.append(make_config(ds, model, "full"))

    # --- Cluster-GCN / SAGE subgraph baselines: full program at batch size -
    for p in SMALL + LARGE:
        pc = make_config(p.name, "gcn", "gas")  # borrow gas shapes
        cfgs.append(ArtifactConfig(
            name=f"{p.name}_gcn2_subg", model="gcn", program="full",
            dataset=p.name, nb=pc.nb + pc.nh, nh=0, e=pc.e, f=p.f, h=64,
            c=p.c, layers=2, loss=pc.loss,
            edge_weight="gcn_norm", scaler_mean=pc.scaler_mean))

    # --- Fig. 4: GIN-4, fixed 4000-node batch, swept halo size -------------
    for i, nh in enumerate([512, 1024, 2048, 4096, 8192, 16384]):
        nb = 4096
        e = _round_up(60 * nb + 60 * nh + 64, 256)
        cfgs.append(ArtifactConfig(
            name=f"fig4_gin4_nh{nh}", model="gin", program="gas",
            dataset="", nb=nb, nh=nh, e=e, f=64, h=64, c=8, layers=4,
            loss="ce", edge_weight="ones", with_reg=False))

    names = [c.name for c in cfgs]
    assert len(names) == len(set(names)), "duplicate artifact names"
    return cfgs


REGISTRY: List[ArtifactConfig] = build_registry()
BY_NAME: Dict[str, ArtifactConfig] = {c.name: c for c in REGISTRY}


def profile_dict(p: DatasetProfile) -> dict:
    return asdict(p)


def config_dict(c: ArtifactConfig) -> dict:
    d = asdict(c)
    d["nt"] = c.nt
    return d
