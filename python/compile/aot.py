"""AOT lowering: every registered artifact -> artifacts/<name>.hlo.txt.

HLO *text* is the interchange format (NOT `lowered.compiler_ir("hlo")
.serialize()`): the rust side's xla_extension 0.5.1 rejects jax>=0.5's
64-bit instruction ids, while the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Also emits artifacts/manifest.json — the Rust coordinator's configuration
root: artifact shapes + input/output orders + parameter init specs + the
synthetic dataset profiles.

Usage:
    python -m compile.aot --out-dir ../artifacts [--filter SUBSTR] [--jobs N]
"""

import argparse
import json
import os
import sys
import time

import jax
from jax._src.lib import xla_client as xc

from . import configs, models


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_one(cfg) -> str:
    step = models.make_train_step(cfg)
    args = models.example_inputs(cfg)
    # keep_unused: the rust marshaller feeds every manifest input, so the
    # HLO signature must retain args the model ignores (e.g. `noise` when
    # the Lipschitz-reg branch is compiled out).
    lowered = jax.jit(step, keep_unused=True).lower(*args)
    return to_hlo_text(lowered)


def manifest_entry(cfg) -> dict:
    specs = models.param_specs(cfg)
    # jax flattens dict pytrees in sorted-key order; manifest mirrors that.
    by_name = dict(specs)
    ordered = sorted(by_name.keys())
    params = [{"name": k, **by_name[k]} for k in ordered]
    d = configs.config_dict(cfg)
    full = cfg.program == "full"
    n_in = cfg.nb if full else cfg.nt
    hist_layers = max(cfg.layers - 1, 0)
    hist_shape = [1, 1, 1] if full else [hist_layers, cfg.nh, cfg.hist_dim]
    labels_shape = [cfg.nb] if cfg.loss == "ce" else [cfg.nb, cfg.c]
    noise_dim = max(cfg.hist_dim, cfg.h)
    inputs = (
        [{"name": p["name"], "kind": "param", "shape": p["shape"],
          "dtype": "f32"} for p in params]
        + [
            {"name": "x", "kind": "x", "shape": [n_in, cfg.f], "dtype": "f32"},
            {"name": "edge_src", "kind": "edge_src", "shape": [cfg.e],
             "dtype": "i32"},
            {"name": "edge_dst", "kind": "edge_dst", "shape": [cfg.e],
             "dtype": "i32"},
            {"name": "edge_w", "kind": "edge_w", "shape": [cfg.e],
             "dtype": "f32"},
            {"name": "hist", "kind": "hist", "shape": hist_shape,
             "dtype": "f32"},
            {"name": "labels", "kind": "labels", "shape": labels_shape,
             "dtype": "i32" if cfg.loss == "ce" else "f32"},
            {"name": "label_mask", "kind": "label_mask", "shape": [cfg.nb],
             "dtype": "f32"},
            {"name": "deg", "kind": "deg", "shape": [n_in], "dtype": "f32"},
            {"name": "noise", "kind": "noise", "shape": [n_in, noise_dim],
             "dtype": "f32"},
            {"name": "reg_lambda", "kind": "reg_lambda", "shape": [],
             "dtype": "f32"},
        ]
    )
    outputs = (
        [{"name": "loss", "shape": []}]
        + [{"name": f"grad_{p['name']}", "shape": p["shape"]} for p in params]
        + [{"name": "push",
            "shape": [hist_layers, cfg.nb, cfg.hist_dim] if not full
            else [hist_layers, cfg.nb, cfg.hist_dim]},
           {"name": "logits", "shape": [cfg.nb, cfg.c]}]
    )
    d.update({
        "file": f"{cfg.name}.hlo.txt",
        "params": params,
        "inputs": inputs,
        "outputs": outputs,
    })
    return d


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--filter", default="")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    todo = [c for c in configs.REGISTRY if args.filter in c.name]
    print(f"lowering {len(todo)} artifacts -> {args.out_dir}", flush=True)

    entries = []
    t_all = time.time()
    for i, cfg in enumerate(todo):
        path = os.path.join(args.out_dir, f"{cfg.name}.hlo.txt")
        entries.append(manifest_entry(cfg))
        if os.path.exists(path) and not args.force:
            print(f"[{i+1}/{len(todo)}] {cfg.name}: cached", flush=True)
            continue
        t0 = time.time()
        try:
            text = lower_one(cfg)
        except Exception as e:  # keep going; report at the end
            print(f"[{i+1}/{len(todo)}] {cfg.name}: FAILED {e}", flush=True)
            entries.pop()
            continue
        with open(path, "w") as f:
            f.write(text)
        print(f"[{i+1}/{len(todo)}] {cfg.name}: {len(text)/1e3:.0f}kB "
              f"in {time.time()-t0:.1f}s", flush=True)

    manifest = {
        "version": 1,
        "profiles": {p.name: configs.profile_dict(p)
                     for p in configs.PROFILES.values()},
        "artifacts": {e["name"]: e for e in entries},
    }
    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {mpath} ({len(entries)} artifacts) "
          f"total {time.time()-t_all:.0f}s", flush=True)
    if len(entries) != len(todo):
        sys.exit(1)


if __name__ == "__main__":
    main()
