"""Shared pytest setup for the L2 compile layer.

Two jobs:

* put ``python/`` on ``sys.path`` so ``from compile import ...`` works no
  matter where pytest is invoked from (CI runs ``python -m pytest
  python/tests -q`` at the repo root);
* skip-if-missing-dependency guards: every test module imports ``jax``
  (directly or through ``compile.models``/``compile.aot``), and the kernel
  sweep additionally needs ``hypothesis``. Bare CI runners have neither,
  so we drop those files from collection instead of erroring — the job
  stays green and reports the skip reason.
"""

import importlib.util
import os
import sys

sys.path.insert(0, os.path.dirname(__file__))


def _missing(mod: str) -> bool:
    return importlib.util.find_spec(mod) is None


collect_ignore = []
if _missing("jax"):
    # all three modules pull in jax at import time
    collect_ignore += [
        "tests/test_kernels.py",
        "tests/test_models.py",
        "tests/test_manifest.py",
    ]
    sys.stderr.write("conftest: jax not installed — skipping L2 tests\n")
elif _missing("hypothesis"):
    collect_ignore += ["tests/test_kernels.py"]
    sys.stderr.write("conftest: hypothesis not installed — skipping kernel sweep\n")
