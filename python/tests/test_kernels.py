"""L1 correctness: Pallas kernels vs pure-jnp oracles (ref.py).

hypothesis sweeps shapes, block sizes, index patterns and values — the CORE
correctness signal for the kernel layer (aggregation is inside every GNN
layer of every artifact).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import aggregate as K
from compile.kernels import ref as R


def _case(seed, n_src, n_out, e, h):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n_src, h)), jnp.float32)
    src = jnp.asarray(rng.integers(0, n_src, e), jnp.int32)
    dst = jnp.asarray(rng.integers(0, n_out, e), jnp.int32)
    w = jnp.asarray(rng.normal(size=e), jnp.float32)
    mask = jnp.asarray(rng.integers(0, 2, e), jnp.float32)
    return x, src, dst, w, mask


shape_st = st.tuples(
    st.integers(0, 2**31 - 1),            # seed
    st.integers(1, 70),                   # n_src
    st.integers(1, 50),                   # n_out
    st.integers(1, 700),                  # edges
    st.integers(1, 33),                   # feature dim
    st.sampled_from([16, 64, 128, 1024]), # block
)


@settings(max_examples=40, deadline=None)
@given(shape_st)
def test_scatter_sum_matches_ref(args):
    seed, n_src, n_out, e, h, block = args
    x, src, dst, w, _ = _case(seed, n_src, n_out, e, h)
    got = K.scatter_sum(x, src, dst, w, n_out, block=block)
    want = R.scatter_sum_ref(x, src, dst, w, n_out)
    np.testing.assert_allclose(got, want, atol=2e-4, rtol=2e-4)


@settings(max_examples=25, deadline=None)
@given(shape_st)
def test_scatter_max_min_match_ref(args):
    seed, n_src, n_out, e, h, block = args
    x, src, dst, _, mask = _case(seed, n_src, n_out, e, h)
    np.testing.assert_allclose(
        K.scatter_max(x, src, dst, mask, n_out, block=block),
        R.scatter_max_ref(x, src, dst, mask, n_out))
    np.testing.assert_allclose(
        K.scatter_min(x, src, dst, mask, n_out, block=block),
        R.scatter_min_ref(x, src, dst, mask, n_out))


@settings(max_examples=25, deadline=None)
@given(shape_st)
def test_scatter_sum_vec_matches_ref(args):
    seed, _, n_out, e, _, block = args
    rng = np.random.default_rng(seed)
    v = jnp.asarray(rng.normal(size=e), jnp.float32)
    dst = jnp.asarray(rng.integers(0, n_out, e), jnp.int32)
    np.testing.assert_allclose(
        K.scatter_sum_vec(v, dst, n_out, block=block),
        R.scatter_sum_vec_ref(v, dst, n_out), atol=2e-4, rtol=2e-4)


@settings(max_examples=20, deadline=None)
@given(shape_st, st.integers(1, 17))
def test_scatter_pair_mlp_matches_ref(args, h_out):
    seed, n_src, n_out, e, h, block = args
    x, src, dst, w, _ = _case(seed, n_src, n_out, e, h)
    rng = np.random.default_rng(seed + 1)
    xd = jnp.asarray(rng.normal(size=(n_out, h)), jnp.float32)
    w1 = jnp.asarray(rng.normal(size=(2 * h, h_out)), jnp.float32)
    got = K.scatter_pair_mlp_sum(x, xd, src, dst, w, w1, n_out, block=block)
    want = R.scatter_pair_mlp_sum_ref(x, xd, src, dst, w, w1, n_out)
    np.testing.assert_allclose(got, want, atol=2e-3, rtol=2e-3)


@settings(max_examples=20, deadline=None)
@given(shape_st)
def test_edge_softmax_parts_match_ref(args):
    seed, _, n_out, e, _, block = args
    rng = np.random.default_rng(seed)
    logits = jnp.asarray(rng.normal(size=e) * 3.0, jnp.float32)
    dst = jnp.asarray(rng.integers(0, n_out, e), jnp.int32)
    mask = jnp.asarray(rng.integers(0, 2, e), jnp.float32)
    m1, d1, e1 = K.edge_softmax_parts(logits, dst, mask, n_out, block=block)
    m2, d2, e2 = R.edge_softmax_parts_ref(logits, dst, mask, n_out)
    np.testing.assert_allclose(m1, m2)
    np.testing.assert_allclose(d1, d2, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(e1, e2, atol=1e-5, rtol=1e-4)


# ----------------------------- gradients ----------------------------------

def test_scatter_sum_grad_matches_ref_grad():
    x, src, dst, w, _ = _case(0, 30, 20, 256, 8)

    def f_kernel(x, w):
        return jnp.sum(K.scatter_sum(x, src, dst, w, 20, block=64) ** 2)

    def f_ref(x, w):
        return jnp.sum(R.scatter_sum_ref(x, src, dst, w, 20) ** 2)

    gx1, gw1 = jax.grad(f_kernel, argnums=(0, 1))(x, w)
    gx2, gw2 = jax.grad(f_ref, argnums=(0, 1))(x, w)
    np.testing.assert_allclose(gx1, gx2, atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(gw1, gw2, atol=1e-3, rtol=1e-3)


def test_scatter_max_grad_matches_ref_grad():
    # distinct values AND unique (src,dst) pairs => unique argmax per dst
    # => the kernel's tie-sharing subgradient equals jnp's. (Real edge
    # lists are duplicate-free; duplicate edges would legitimately split
    # the subgradient differently between the two implementations.)
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.permutation(30 * 8).reshape(30, 8) * 0.01, jnp.float32)
    pairs = rng.permutation(30 * 20)[:128]
    src = jnp.asarray(pairs // 20, jnp.int32)
    dst = jnp.asarray(pairs % 20, jnp.int32)
    mask = jnp.ones(128, jnp.float32)

    def f_kernel(x):
        return jnp.sum(K.scatter_max(x, src, dst, mask, 20, block=64) ** 2)

    def f_ref(x):
        return jnp.sum(R.scatter_max_ref(x, src, dst, mask, 20) ** 2)

    np.testing.assert_allclose(jax.grad(f_kernel)(x), jax.grad(f_ref)(x),
                               atol=1e-4, rtol=1e-4)


def test_scatter_pair_grad_matches_ref_grad():
    x, src, dst, w, _ = _case(5, 30, 20, 256, 8)
    rng = np.random.default_rng(6)
    xd = jnp.asarray(rng.normal(size=(20, 8)), jnp.float32)
    w1 = jnp.asarray(rng.normal(size=(16, 5)), jnp.float32)

    def f_kernel(xs, xd, w1):
        return jnp.sum(
            K.scatter_pair_mlp_sum(xs, xd, src, dst, w, w1, 20, block=64) ** 2)

    def f_ref(xs, xd, w1):
        return jnp.sum(
            R.scatter_pair_mlp_sum_ref(xs, xd, src, dst, w, w1, 20) ** 2)

    g1 = jax.grad(f_kernel, argnums=(0, 1, 2))(x, xd, w1)
    g2 = jax.grad(f_ref, argnums=(0, 1, 2))(x, xd, w1)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, atol=2e-3, rtol=2e-3)


def test_scatter_sum_inside_scan_differentiates():
    """Regression: custom_vjp closures used to break under lax.scan (GCNII)."""
    x, src, dst, w, _ = _case(1, 16, 16, 64, 4)
    ws = jnp.asarray(np.random.default_rng(2).normal(size=(3, 4, 4)),
                     jnp.float32)

    def model(ws):
        def step(h, wl):
            return jax.nn.relu(
                K.scatter_sum(h, src, dst, w, 16, block=64) @ wl), None
        h, _ = jax.lax.scan(step, x, ws)
        return jnp.sum(h ** 2)

    g = jax.grad(model)(ws)
    assert g.shape == (3, 4, 4)
    assert bool(jnp.all(jnp.isfinite(g)))


# ----------------------------- edge cases ----------------------------------

def test_padded_edges_contribute_nothing():
    x, src, dst, w, _ = _case(7, 10, 8, 64, 4)
    w_padded = jnp.concatenate([w, jnp.zeros(64, jnp.float32)])
    src_p = jnp.concatenate([src, jnp.zeros(64, jnp.int32)])
    dst_p = jnp.concatenate([dst, jnp.zeros(64, jnp.int32)])
    a = K.scatter_sum(x, src, dst, w, 8, block=32)
    b = K.scatter_sum(x, src_p, dst_p, w_padded, 8, block=32)
    np.testing.assert_allclose(a, b, atol=1e-5)


def test_isolated_destinations_are_zero():
    x = jnp.ones((4, 3), jnp.float32)
    src = jnp.asarray([0, 1], jnp.int32)
    dst = jnp.asarray([0, 0], jnp.int32)
    w = jnp.ones(2, jnp.float32)
    out = K.scatter_sum(x, src, dst, w, 5, block=2)
    np.testing.assert_allclose(out[1:], np.zeros((4, 3)))
    out = K.scatter_max(x, src, dst, w, 5, block=2)
    np.testing.assert_allclose(out[1:], np.zeros((4, 3)))


def test_block_not_dividing_edge_count():
    # _choose_block must fall back to a divisor; numerics unchanged.
    x, src, dst, w, _ = _case(9, 12, 9, 97, 5)  # 97 is prime
    a = K.scatter_sum(x, src, dst, w, 9, block=64)
    b = R.scatter_sum_ref(x, src, dst, w, 9)
    np.testing.assert_allclose(a, b, atol=1e-4, rtol=1e-4)
