"""L2 correctness: model semantics.

The key invariant (paper Eq. 2): with *exact* histories, the GAS program
produces exactly the full-batch embeddings for in-batch nodes. Plus dense
references for the operators and loss functions.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import models
from compile.configs import ArtifactConfig


# --------------------------------------------------------------------------
# tiny deterministic test graph: n nodes, undirected ring + chords
# --------------------------------------------------------------------------

def tiny_graph(n=12, extra=6, seed=0):
    rng = np.random.default_rng(seed)
    und = {(i, (i + 1) % n) for i in range(n)}
    while len(und) < n + extra:
        a, b = rng.integers(0, n, 2)
        if a != b:
            und.add((min(a, b), max(a, b)))
    src, dst = [], []
    for a, b in sorted(und):
        src += [a, b]
        dst += [b, a]
    return np.array(src, np.int32), np.array(dst, np.int32)


def degrees(src, dst, n):
    deg = np.zeros(n, np.float32)
    for d in dst:
        deg[d] += 1
    return deg


def gcn_w(src, dst, deg):
    return (1.0 / (np.sqrt(deg[src] + 1) * np.sqrt(deg[dst] + 1))).astype(
        np.float32)


def make_cfg(model, program, n, nb, nh, e, f=5, h=8, c=3, layers=2,
             with_reg=False, loss="ce"):
    return ArtifactConfig(
        name="t", model=model, program=program, dataset="t", nb=nb, nh=nh,
        e=e, f=f, h=h, c=c, layers=layers, loss=loss, heads=2,
        with_reg=with_reg, edge_weight="ones", scaler_mean=1.0, block=64)


def init_params(cfg, seed=0):
    rng = np.random.default_rng(seed)
    out = {}
    for name, spec in models.param_specs(cfg):
        shape = spec["shape"]
        if spec["init"] == "zeros":
            out[name] = jnp.zeros(shape, jnp.float32)
        else:
            fan = shape[0] if len(shape) > 1 else 1
            out[name] = jnp.asarray(
                rng.normal(size=shape) / np.sqrt(max(fan, 1)), jnp.float32)
    return out


def run_full(cfg, p, x, src, dst, w, deg):
    hist = jnp.zeros((1, 1, 1), jnp.float32)
    noise = jnp.zeros((cfg.nb, max(cfg.hist_dim, cfg.h)), jnp.float32)
    return models.RUNNERS[cfg.model](p, cfg, x, src, dst, w, hist, deg,
                                     noise, True)


N = 12


class TestExactHistoryEquivalence:
    """GAS(exact histories) == full-batch, per operator (Eq. 2 line 1)."""

    @pytest.mark.parametrize("model,layers",
                             [("gcn", 3), ("gin", 3), ("gcnii", 4),
                              ("appnp", 4), ("gat", 2), ("pna", 2)])
    def test_equivalence(self, model, layers):
        src, dst, = tiny_graph(N)
        deg = degrees(src, dst, N)
        f = 5
        rng = np.random.default_rng(1)
        x = jnp.asarray(rng.normal(size=(N, f)), jnp.float32)
        w_ones = jnp.ones(len(src), jnp.float32)
        w_gcn = jnp.asarray(gcn_w(src, dst, deg))
        w = w_gcn if model in ("gcn", "gcnii", "appnp") else w_ones

        cfg_full = make_cfg(model, "full", N, N, 0, len(src), layers=layers)
        p = init_params(cfg_full, seed=2)
        logits_full, push_full, _ = run_full(
            cfg_full, p, x, jnp.asarray(src), jnp.asarray(dst), w,
            jnp.asarray(deg))

        # batch = first half of nodes, halo = the rest (order preserved)
        nb = N // 2
        batch = np.arange(nb)
        halo = np.arange(nb, N)
        cfg_gas = dataclasses.replace(cfg_full, program="gas", nb=nb,
                                      nh=len(halo))
        # keep only edges with dst in batch; src stays in global numbering
        keep = dst < nb
        gsrc = jnp.asarray(src[keep])
        gdst = jnp.asarray(dst[keep])
        gw = w[np.where(keep)[0]]
        hist_layers = layers - 1
        hd = cfg_gas.hist_dim
        # exact histories for halo nodes, from the full run
        hist = jnp.stack([push_full[l][halo, :hd]
                          for l in range(hist_layers)], axis=0)
        noise = jnp.zeros((N, max(hd, cfg_gas.h)), jnp.float32)
        logits_gas, push_gas, _ = models.RUNNERS[model](
            p, cfg_gas, x, gsrc, gdst, gw, hist, jnp.asarray(deg), noise,
            False)

        np.testing.assert_allclose(logits_gas, logits_full[:nb],
                                   atol=2e-4, rtol=2e-4)
        for l in range(hist_layers):
            np.testing.assert_allclose(push_gas[l], push_full[l][:nb],
                                       atol=2e-4, rtol=2e-4)


class TestDenseReferences:
    def test_gcn_layer_matches_dense(self):
        src, dst = tiny_graph(N)
        deg = degrees(src, dst, N)
        w = gcn_w(src, dst, deg)
        rng = np.random.default_rng(4)
        x = rng.normal(size=(N, 5)).astype(np.float32)
        cfg = make_cfg("gcn", "full", N, N, 0, len(src), layers=1, c=3)
        p = init_params(cfg, 5)
        logits, _, _ = run_full(cfg, p, jnp.asarray(x), jnp.asarray(src),
                                jnp.asarray(dst), jnp.asarray(w),
                                jnp.asarray(deg))
        # dense: A_hat = D^-1/2 (A + I) D^-1/2 ; out = A_hat X W + b
        a = np.zeros((N, N), np.float32)
        a[dst, src] = w
        a[np.arange(N), np.arange(N)] = 1.0 / (deg + 1)
        want = a @ x @ np.asarray(p["w0"]) + np.asarray(p["b0"])
        np.testing.assert_allclose(logits, want, atol=1e-4, rtol=1e-4)

    def test_gat_attention_rows_sum_to_one(self):
        src, dst = tiny_graph(N)
        deg = degrees(src, dst, N)
        rng = np.random.default_rng(5)
        x = jnp.asarray(rng.normal(size=(N, 5)), jnp.float32)
        cfg = make_cfg("gat", "full", N, N, 0, len(src), layers=1, c=4)
        p = init_params(cfg, 6)
        # constant unit features through an identity-ish W would need exact
        # row-stochastic check; instead verify output is convex combination:
        # all-equal inputs => output equals (any) transformed input + bias.
        x_const = jnp.ones((N, 5), jnp.float32)
        logits, _, _ = run_full(cfg, p, x_const, jnp.asarray(src),
                                jnp.asarray(dst),
                                jnp.ones(len(src), jnp.float32),
                                jnp.asarray(deg))
        want = x_const[:1] @ p["w0"] + p["b0"]
        np.testing.assert_allclose(logits, np.tile(want, (N, 1)),
                                   atol=1e-4, rtol=1e-4)

    def test_appnp_propagation_is_personalized_pagerank_step(self):
        src, dst = tiny_graph(N)
        deg = degrees(src, dst, N)
        w = gcn_w(src, dst, deg)
        rng = np.random.default_rng(7)
        x = rng.normal(size=(N, 5)).astype(np.float32)
        cfg = make_cfg("appnp", "full", N, N, 0, len(src), layers=3, c=3)
        p = init_params(cfg, 8)
        logits, _, _ = run_full(cfg, p, jnp.asarray(x), jnp.asarray(src),
                                jnp.asarray(dst), jnp.asarray(w),
                                jnp.asarray(deg))
        a = np.zeros((N, N), np.float32)
        a[dst, src] = w
        a[np.arange(N), np.arange(N)] = 1.0 / (deg + 1)
        z = np.maximum(x @ np.asarray(p["mlp_w1"]) + np.asarray(p["mlp_b1"]),
                       0)
        h0 = z @ np.asarray(p["mlp_w2"]) + np.asarray(p["mlp_b2"])
        h = h0
        for _ in range(3):
            h = (1 - cfg.alpha) * (a @ h) + cfg.alpha * h0
        np.testing.assert_allclose(logits, h, atol=1e-4, rtol=1e-4)

    def test_gin_sum_aggregation(self):
        src, dst = tiny_graph(N)
        deg = degrees(src, dst, N)
        rng = np.random.default_rng(9)
        x = rng.normal(size=(N, 5)).astype(np.float32)
        cfg = make_cfg("gin", "full", N, N, 0, len(src), layers=1)
        p = init_params(cfg, 10)
        logits, _, _ = run_full(cfg, p, jnp.asarray(x), jnp.asarray(src),
                                jnp.asarray(dst),
                                jnp.ones(len(src), jnp.float32),
                                jnp.asarray(deg))
        a = np.zeros((N, N), np.float32)
        a[dst, src] = 1.0
        pre = (1.0 + np.asarray(p["eps0"])[0]) * x + a @ x
        z = np.maximum(pre @ np.asarray(p["mlp0_w1"]) +
                       np.asarray(p["mlp0_b1"]), 0)
        hid = z @ np.asarray(p["mlp0_w2"]) + np.asarray(p["mlp0_b2"])
        want = np.maximum(hid, 0) @ np.asarray(p["head_w"]) + \
            np.asarray(p["head_b"])
        np.testing.assert_allclose(logits, want, atol=1e-4, rtol=1e-4)


class TestLosses:
    def test_softmax_ce_masked(self):
        logits = jnp.asarray([[2.0, 0.0], [0.0, 3.0], [1.0, 1.0]])
        labels = jnp.asarray([0, 1, 0], jnp.int32)
        mask = jnp.asarray([1.0, 1.0, 0.0])
        got = models.softmax_ce(logits, labels, mask)
        p0 = np.exp(2.0) / (np.exp(2.0) + 1.0)
        p1 = np.exp(3.0) / (np.exp(3.0) + 1.0)
        want = -(np.log(p0) + np.log(p1)) / 2
        np.testing.assert_allclose(got, want, rtol=1e-5)

    def test_softmax_ce_zero_mask_is_finite(self):
        logits = jnp.ones((3, 2))
        labels = jnp.zeros(3, jnp.int32)
        assert np.isfinite(float(models.softmax_ce(logits, labels,
                                                   jnp.zeros(3))))

    def test_bce_multilabel(self):
        logits = jnp.asarray([[0.0, 10.0], [-10.0, 0.0]])
        labels = jnp.asarray([[0.0, 1.0], [0.0, 1.0]])
        mask = jnp.asarray([1.0, 1.0])
        got = float(models.bce_multilabel(logits, labels, mask))
        # row0: -(log .5 + log sig(10))/2 ; row1: -(log sig(10) + log .5)/2
        want = -(np.log(0.5) + np.log(1 / (1 + np.exp(-10.0)))) / 2
        np.testing.assert_allclose(got, want, rtol=1e-4)


class TestTrainStep:
    def test_gradients_flow_and_push_shapes(self):
        src, dst = tiny_graph(N)
        deg = degrees(src, dst, N)
        nb, layers = 6, 3
        keep = dst < nb
        cfg = make_cfg("gcn", "gas", N, nb, N - nb, int(keep.sum()),
                       layers=layers)
        step = models.make_train_step(cfg)
        p = init_params(cfg, 11)
        rng = np.random.default_rng(12)
        x = jnp.asarray(rng.normal(size=(N, 5)), jnp.float32)
        hist = jnp.asarray(rng.normal(size=(layers - 1, N - nb, cfg.h)),
                           jnp.float32)
        noise = jnp.zeros((N, cfg.h), jnp.float32)
        labels = jnp.asarray(rng.integers(0, 3, nb), jnp.int32)
        lmask = jnp.ones(nb, jnp.float32)
        w = jnp.asarray(gcn_w(src, dst, deg))[np.where(keep)[0]]
        loss, grads, push, logits = step(
            p, x, jnp.asarray(src[keep]), jnp.asarray(dst[keep]), w, hist,
            labels, lmask, jnp.asarray(deg), noise, jnp.asarray(0.0))
        assert np.isfinite(float(loss))
        assert push.shape == (layers - 1, nb, cfg.h)
        assert logits.shape == (nb, 3)
        total = sum(float(jnp.sum(jnp.abs(g))) for g in grads.values())
        assert total > 0

    def test_history_influences_output_but_not_used_when_no_halo_edges(self):
        src, dst = tiny_graph(N)
        deg = degrees(src, dst, N)
        nb = 6
        keep = dst < nb
        cfg = make_cfg("gcn", "gas", N, nb, N - nb, int(keep.sum()),
                       layers=3)
        step = models.make_train_step(cfg)
        p = init_params(cfg, 13)
        rng = np.random.default_rng(14)
        x = jnp.asarray(rng.normal(size=(N, 5)), jnp.float32)
        noise = jnp.zeros((N, cfg.h), jnp.float32)
        labels = jnp.asarray(rng.integers(0, 3, nb), jnp.int32)
        lmask = jnp.ones(nb, jnp.float32)
        w = jnp.asarray(gcn_w(src, dst, deg))[np.where(keep)[0]]
        args = (jnp.asarray(src[keep]), jnp.asarray(dst[keep]), w)

        h1 = jnp.zeros((2, N - nb, cfg.h), jnp.float32)
        h2 = jnp.ones((2, N - nb, cfg.h), jnp.float32)
        l1 = step(p, x, *args, h1, labels, lmask, jnp.asarray(deg), noise,
                  jnp.asarray(0.0))[0]
        l2 = step(p, x, *args, h2, labels, lmask, jnp.asarray(deg), noise,
                  jnp.asarray(0.0))[0]
        assert abs(float(l1) - float(l2)) > 1e-8  # histories are live

        # with halo edges cut (w=0 on cross edges), histories are dead
        cross = np.asarray(src[keep]) >= nb
        wcut = jnp.where(jnp.asarray(cross), 0.0, w)
        l3 = step(p, x, args[0], args[1], wcut, h1, labels, lmask,
                  jnp.asarray(deg), noise, jnp.asarray(0.0))[0]
        l4 = step(p, x, args[0], args[1], wcut, h2, labels, lmask,
                  jnp.asarray(deg), noise, jnp.asarray(0.0))[0]
        np.testing.assert_allclose(float(l3), float(l4), rtol=1e-6)

    def test_reg_lambda_changes_loss_for_gin(self):
        src, dst = tiny_graph(N)
        deg = degrees(src, dst, N)
        nb = 6
        keep = dst < nb
        cfg = make_cfg("gin", "gas", N, nb, N - nb, int(keep.sum()),
                       layers=3, with_reg=True)
        step = models.make_train_step(cfg)
        p = init_params(cfg, 15)
        rng = np.random.default_rng(16)
        x = jnp.asarray(rng.normal(size=(N, 5)), jnp.float32)
        hist = jnp.asarray(rng.normal(size=(2, N - nb, cfg.h)), jnp.float32)
        noise = jnp.asarray(rng.normal(size=(N, cfg.h)) * 0.1, jnp.float32)
        labels = jnp.asarray(rng.integers(0, 3, nb), jnp.int32)
        lmask = jnp.ones(nb, jnp.float32)
        w = jnp.ones(int(keep.sum()), jnp.float32)
        common = (p, x, jnp.asarray(src[keep]), jnp.asarray(dst[keep]), w,
                  hist, labels, lmask, jnp.asarray(deg), noise)
        l0 = float(step(*common, jnp.asarray(0.0))[0])
        l1 = float(step(*common, jnp.asarray(10.0))[0])
        assert l1 > l0
