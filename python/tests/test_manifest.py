"""Manifest integrity: registry shapes, input/output orders, profiles."""

import json
import os

import pytest

from compile import configs, models
from compile.aot import manifest_entry


def test_registry_has_no_duplicate_names():
    names = [c.name for c in configs.REGISTRY]
    assert len(names) == len(set(names))


def test_all_small_datasets_have_four_models_both_programs():
    names = {c.name for c in configs.REGISTRY}
    for p in configs.SMALL:
        for m, l in [("gcn", 2), ("gat", 2), ("appnp", 10), ("gcnii", 8)]:
            assert f"{p.name}_{m}{l}_gas" in names
            assert f"{p.name}_{m}{l}_full" in names


def test_manifest_entry_input_order_matches_jax_flattening():
    """jax flattens dict pytrees sorted by key — manifest must mirror it."""
    cfg = configs.BY_NAME["cora_gcn2_gas"]
    entry = manifest_entry(cfg)
    param_names = [i["name"] for i in entry["inputs"] if i["kind"] == "param"]
    assert param_names == sorted(param_names)
    kinds = [i["kind"] for i in entry["inputs"] if i["kind"] != "param"]
    assert kinds == ["x", "edge_src", "edge_dst", "edge_w", "hist", "labels",
                     "label_mask", "deg", "noise", "reg_lambda"]


def test_manifest_entry_outputs():
    cfg = configs.BY_NAME["cora_gcnii8_gas"]
    entry = manifest_entry(cfg)
    outs = [o["name"] for o in entry["outputs"]]
    assert outs[0] == "loss"
    assert outs[-2:] == ["push", "logits"]
    assert len(outs) == 1 + len(entry["params"]) + 2


def test_param_specs_match_example_inputs():
    for name in ["cora_gcn2_gas", "cluster_gin4_gas", "ppi_pna3_gas",
                 "cora_gat2_full", "cora_appnp10_gas",
                 "cora_gcnii64_gas_deep"]:
        cfg = configs.BY_NAME[name]
        args = models.example_inputs(cfg)
        params = args[0]
        specs = dict(models.param_specs(cfg))
        assert set(params.keys()) == set(specs.keys())
        for k, v in params.items():
            assert list(v.shape) == specs[k]["shape"], (name, k)


def test_multilabel_configs_use_bce_and_2d_labels():
    cfg = configs.BY_NAME["ppi_gcn2_gas"]
    assert cfg.loss == "bce"
    entry = manifest_entry(cfg)
    lab = [i for i in entry["inputs"] if i["kind"] == "labels"][0]
    assert lab["shape"] == [cfg.nb, cfg.c]
    assert lab["dtype"] == "f32"


def test_full_program_has_no_halo():
    cfg = configs.BY_NAME["cora_gcn2_full"]
    assert cfg.nh == 0
    assert cfg.nb == configs.PROFILES["cora"].n


@pytest.mark.skipif(not os.path.exists(
    os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.json")),
    reason="artifacts not built")
def test_written_manifest_covers_registry():
    path = os.path.join(os.path.dirname(__file__),
                        "../../artifacts/manifest.json")
    with open(path) as f:
        m = json.load(f)
    assert set(m["artifacts"].keys()) == {c.name for c in configs.REGISTRY}
    for name, entry in m["artifacts"].items():
        assert os.path.exists(os.path.join(os.path.dirname(path),
                                           entry["file"])), name
    assert set(m["profiles"].keys()) == set(configs.PROFILES.keys())
