#!/usr/bin/env python3
"""Gate the CI bench-smoke job on BENCH_error_bounds.json (codec parity).

The error_bounds bench trains gcn2 and gcnii8 on cora at equal steps under
f32 / f16 / int8 history codecs on the bit-deterministic Serial schedule
(pull_depth=1), so the history codec is the ONLY difference between the
runs of one model. This script makes the quantized-history claim
enforceable — compressed histories buy their storage win without giving
back convergence:

  * equal footing — each compressed run must report exactly the same step
    count as its f32 sibling (otherwise the accuracy comparison is
    meaningless);
  * convergence parity — final validation accuracy under f16 and int8
    must not drop more than a small epsilon below the f32 run of the same
    model at equal steps (the codec analog of the Theorem-2 bounded-error
    claim);
  * real compression — stored/logical byte ratios must clear the same
    caps the table3 gate enforces (<= 0.55x for f16, <= 0.30x for int8)
    and sit at ~1.0 for f32;
  * live telemetry — the compressed runs must report a positive
    quantization error with mean <= max (a zero reading means the sampled
    push-error probe is dead), and the f32 runs must report zero.

Thresholds are overridable via env for local experimentation:

    GAS_EB_MAX_ACC_DROP    (default 0.05 absolute val-accuracy points;
                            cora val accuracy lands ~0.7x, so 0.05 is a
                            real-regression threshold, not seed noise on
                            this fixed-seed deterministic schedule)
    GAS_BENCH_MAX_F16_RATIO   (default 0.55, shared with the table3 gate)
    GAS_BENCH_MAX_INT8_RATIO  (default 0.30, shared with the table3 gate)

Usage: python3 ci/check_bench_error_bounds.py [BENCH_error_bounds.json]
"""
import json
import os
import sys

MODELS = ("gcn2", "gcnii8")
COMPRESSED = ("f16", "int8")


def main() -> int:
    path = sys.argv[1] if len(sys.argv) > 1 else "BENCH_error_bounds.json"
    with open(path) as f:
        rec = json.load(f)

    max_drop = float(os.environ.get("GAS_EB_MAX_ACC_DROP", "0.05"))
    ratio_caps = {
        "f16": float(os.environ.get("GAS_BENCH_MAX_F16_RATIO", "0.55")),
        "int8": float(os.environ.get("GAS_BENCH_MAX_INT8_RATIO", "0.30")),
    }

    metrics = rec["metrics"]
    failures = []

    for model in MODELS:
        f32_val = metrics[f"{model}_f32_val_acc"]
        f32_steps = metrics[f"{model}_f32_steps"]
        f32_ratio = metrics[f"{model}_f32_stored_ratio"]
        print(f"{model} [f32]: val {f32_val:.4f} @ {f32_steps:.0f} steps, "
              f"stored/logical {f32_ratio:.3f}")
        if abs(f32_ratio - 1.0) > 1e-6:
            failures.append(
                f"{model} f32 stored/logical {f32_ratio:.4f} != 1.0 — "
                "the uncompressed backing's byte accounting is broken"
            )
        if metrics[f"{model}_f32_quant_err_max"] != 0.0:
            failures.append(
                f"{model} f32 reports nonzero quantization error — the f32 "
                "path must be exact"
            )

        for codec in COMPRESSED:
            val = metrics[f"{model}_{codec}_val_acc"]
            steps = metrics[f"{model}_{codec}_steps"]
            ratio = metrics[f"{model}_{codec}_stored_ratio"]
            qmax = metrics[f"{model}_{codec}_quant_err_max"]
            qmean = metrics[f"{model}_{codec}_quant_err_mean"]
            drop = f32_val - val
            print(f"{model} [{codec}]: val {val:.4f} (drop {drop:+.4f}, "
                  f"budget {max_drop}) @ {steps:.0f} steps, "
                  f"stored/logical {ratio:.3f} (cap {ratio_caps[codec]}), "
                  f"qerr max {qmax:.3e} mean {qmean:.3e}")
            if steps != f32_steps:
                failures.append(
                    f"{model} {codec} ran {steps:.0f} steps vs f32's "
                    f"{f32_steps:.0f} — accuracy comparison is not at equal steps"
                )
            if drop > max_drop:
                failures.append(
                    f"{model} {codec} val accuracy {val:.4f} drops "
                    f"{drop:.4f} below f32's {f32_val:.4f} "
                    f"(budget {max_drop}) — quantized history hurts convergence"
                )
            if ratio > ratio_caps[codec]:
                failures.append(
                    f"{model} {codec} stored/logical {ratio:.4f} over the "
                    f"{ratio_caps[codec]} cap — codec is not compressing"
                )
            if not (0.0 < qmean <= qmax):
                failures.append(
                    f"{model} {codec} quantization telemetry broken: "
                    f"mean {qmean:.3e}, max {qmax:.3e} (expected 0 < mean <= max)"
                )

    if failures:
        print("\nCODEC PARITY GATE FAILED:")
        for msg in failures:
            print(f"  {msg}")
        return 1
    print("codec parity gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
