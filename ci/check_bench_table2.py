#!/usr/bin/env python3
"""Gate the CI bench-smoke job on BENCH_table2.json (staleness control loop).

The table2 bench's staleness sweep trains cora/gcnii8 four times on the
bit-deterministic Serial schedule (pull_depth=1) at an equal epoch budget,
varying ONLY the control-loop knob per arm: round-robin scheduling (the
default path), staleness-ordered scheduling, delta-skip pushes, and the
between-epoch priority refresh. This script makes the "staleness is a
control knob, not just a diagnostic" claim enforceable:

  * equal footing — every arm must report exactly the same optimizer-step
    count as the round-robin arm (a refresh pass or a reordered schedule
    that sneaks in extra optimization makes the comparison meaningless);
  * scheduling parity — staleness-ordered val accuracy must not drop more
    than GAS_T2_MAX_ACC_DROP below round-robin at equal steps (reordering
    epochs by accumulated halo staleness must not cost convergence);
  * delta-skip is live AND cheap — the delta-skip arm must report > 0
    skipped pushes (the filter actually fired; the bench's adaptive
    threshold guarantees skippable late-epoch pushes) at a val accuracy
    within the same tolerance, and its threshold must be positive (a 0.0
    threshold is the exact unfiltered path — the arm tested nothing);
  * refresh is live and free — the refresh arm must report > 0 refreshed
    rows at a val accuracy within tolerance, on the same step budget
    (refresh passes are forward-only; they must never tick the optimizer).

Thresholds are overridable via env for local experimentation:

    GAS_T2_MAX_ACC_DROP    (default 0.05 absolute val-accuracy points —
                            the same fixed-seed, deterministic-schedule
                            regression threshold the codec-parity gate
                            uses; cora val accuracy lands ~0.7x)

Usage: python3 ci/check_bench_table2.py [BENCH_table2.json]
"""
import json
import os
import sys

# arms compared against the round-robin reference, with the liveness
# metric proving the knob under test actually engaged
ARMS = (
    ("stale", "staleness-ordered scheduling", None),
    ("skip", "delta-skip pushes", "skip_skipped_pushes"),
    ("refresh", "priority refresh", "refresh_rows"),
)


def main() -> int:
    path = sys.argv[1] if len(sys.argv) > 1 else "BENCH_table2.json"
    with open(path) as f:
        rec = json.load(f)

    max_drop = float(os.environ.get("GAS_T2_MAX_ACC_DROP", "0.05"))
    metrics = rec["metrics"]
    failures = []

    rr_val = metrics["rr_val_acc"]
    rr_steps = metrics["rr_steps"]
    print(f"round-robin: val {rr_val:.4f} @ {rr_steps:.0f} steps, "
          f"staleness(last) {metrics['rr_staleness_last']:.3f}")
    if rr_steps <= 0:
        failures.append("round-robin arm reports no optimizer steps — the sweep did not run")

    for key, label, liveness in ARMS:
        val = metrics[f"{key}_val_acc"]
        steps = metrics[f"{key}_steps"]
        drop = rr_val - val
        extra = ""
        if liveness:
            extra = f", {liveness} {metrics[liveness]:.0f}"
        print(f"{key}: val {val:.4f} (drop {drop:+.4f}, budget {max_drop}) "
              f"@ {steps:.0f} steps{extra}")
        if steps != rr_steps:
            failures.append(
                f"{label} ran {steps:.0f} steps vs round-robin's {rr_steps:.0f} — "
                "accuracy comparison is not at equal steps"
            )
        if drop > max_drop:
            failures.append(
                f"{label} val accuracy {val:.4f} drops {drop:.4f} below "
                f"round-robin's {rr_val:.4f} (budget {max_drop}) — the control "
                "loop hurts convergence"
            )
        if liveness and metrics[liveness] <= 0:
            failures.append(
                f"{label} reports {liveness} = {metrics[liveness]:.0f} — the "
                "knob under test never engaged, the arm is vacuous"
            )

    if metrics["skip_delta_min"] <= 0.0:
        failures.append(
            f"delta-skip threshold {metrics['skip_delta_min']:.3e} <= 0 — "
            "a non-positive threshold is the exact unfiltered push path, "
            "the delta-skip arm tested nothing"
        )
    # the staleness curve itself must be live: an all-zero reading means
    # the per-step staleness feedback into the tracker is dead
    if metrics["rr_staleness_last"] <= 0.0:
        failures.append(
            f"round-robin final-epoch staleness {metrics['rr_staleness_last']:.3f} "
            "<= 0 — the staleness telemetry feeding the scheduler is dead"
        )

    if failures:
        print("\nSTALENESS CONTROL-LOOP GATE FAILED:")
        for msg in failures:
            print(f"  {msg}")
        return 1
    print("staleness control-loop gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
