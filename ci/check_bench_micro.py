#!/usr/bin/env python3
"""Gate the CI bench-smoke job on BENCH_micro.json.

Exits non-zero when the sharded history pull/push medians blow an absolute
budget, when the sharded-vs-serial speedup falls below a floor, when the
blocked GEMM/SpMM/edge-softmax kernels stop clearing their per-shape
throughput floors or the blocked-vs-scalar speedup floors on the gated
n=10k shapes, when any native per-model train-step row (gcn2 / gat2 /
appnp10 — their presence also proves the models actually run natively)
blows its budget or goes missing, when the kernel-ISA dispatch rows go
missing or the auto tier resolves below the 8-lane blocked path (or the
forced-v16 rows miss their throughput floors on runners where the wide
tier is detected), or when the pull_depth=2 pipelined epoch falls behind
the serial epoch.
The history/throughput budgets are deliberately loose: shared CI runners
are noisy, so those catch order-of-magnitude regressions (and near-hangs
shorter than the job timeout), not few-percent drift; the GEMM/SpMM
speedup floors are real product claims (the blocked kernels must beat the
scalar oracles ≥ 2x on the dims that dominate native step time), while
the pipeline-overlap floor only catches "pipelining made epochs clearly
slower" (0.9, leaving margin for runner noise on saturated 2-vCPU
runners) — the actual overlap win is tracked by the trajectory gate on
the two pipeline-epoch rows. Thresholds are overridable via env for
local experimentation:

    GAS_BENCH_MAX_PULL_MS          (default 250)
    GAS_BENCH_MAX_PUSH_MS          (default 500)
    GAS_BENCH_MIN_SPEEDUP          (default 0.6)
    GAS_BENCH_MIN_GEMM_GFLOPS      (default 1.0, every blocked shape)
    GAS_BENCH_MIN_GEMM_SPEEDUP     (default 2.0, n=10k shapes)
    GAS_BENCH_MIN_SPMM_GEDGES      (default 0.02, every blocked shape)
    GAS_BENCH_MIN_SPMM_SPEEDUP     (default 2.0, n=10k shapes)
    GAS_BENCH_MIN_ATTN_GEDGES      (default 0.005, every blocked shape)
    GAS_BENCH_MIN_ATTN_SPEEDUP     (default 1.2, n=10k shapes; the scalar
                                    oracle is serial softmax math, so the
                                    floor is looser than the SpMM one —
                                    the win is tracked by the trajectory)
    GAS_BENCH_MIN_GEMM_V16_GFLOPS  (default 1.0, the forced-v16 n=10k gemm
                                    row; applied only when the bench record
                                    says the wide tier was detected
                                    (`kernel_isa_wide`), with a logged skip
                                    otherwise — a v16 floor on an AVX2-only
                                    runner would gate emulated shuffles)
    GAS_BENCH_MIN_SPMM_V16_GEDGES  (default 0.02, the forced-v16 n=10k deg8
                                    scatter row; same wide-detection gate)
    GAS_BENCH_MAX_STEP_MS          (default 2000, every native train-step
                                    row; loose — catches hangs, not drift)
    GAS_BENCH_MIN_OVERLAP_SPEEDUP  (default 0.9, pipelined vs serial epoch)
    GAS_BENCH_MAX_CODEC_RATIO      (default 4.0, f16/int8 pull+push medians
                                    vs the sharded f32 rows; dequantize math
                                    is allowed to cost, but not an order of
                                    magnitude — the actual trend is tracked
                                    by the trajectory gate on the codec rows)
    GAS_BENCH_MAX_CKPT_RATIO       (default 1.0, checkpoint manifest save
                                    and resume-load medians vs the serial
                                    training epoch — an epoch-boundary
                                    checkpoint may never double epoch cost,
                                    so the whole save+restore round trip
                                    must stay within one epoch's time)

Usage: python3 ci/check_bench_micro.py [BENCH_micro.json]
"""
import json
import os
import sys

GEMM_OPS = ("fwd", "bt", "atb")
GEMM_SHAPES = ("n1k", "n10k")
GEMM_GATED_SHAPE = "n10k"
SPMM_OPS = ("fwd", "bwd")
SPMM_SHAPES = ("n1k_deg8", "n1k_deg32", "n10k_deg8", "n10k_deg32")
SPMM_GATED_SHAPES = ("n10k_deg8", "n10k_deg32")
ATTN_SHAPES = ("n1k_deg8", "n1k_deg32", "n10k_deg8", "n10k_deg32")
ATTN_GATED_SHAPES = ("n10k_deg8", "n10k_deg32")
# the per-model native train-step rows that must exist (and fit the
# budget) whenever the bench ran on the native backend
STEP_MODELS = ("cora_gcn2_gas", "cora_gat2_gas", "cora_appnp10_gas")


def main() -> int:
    path = sys.argv[1] if len(sys.argv) > 1 else "BENCH_micro.json"
    with open(path) as f:
        rec = json.load(f)

    pull_budget_ms = float(os.environ.get("GAS_BENCH_MAX_PULL_MS", "250"))
    push_budget_ms = float(os.environ.get("GAS_BENCH_MAX_PUSH_MS", "500"))
    speedup_floor = float(os.environ.get("GAS_BENCH_MIN_SPEEDUP", "0.6"))
    gemm_gflops_floor = float(os.environ.get("GAS_BENCH_MIN_GEMM_GFLOPS", "1.0"))
    gemm_speedup_floor = float(os.environ.get("GAS_BENCH_MIN_GEMM_SPEEDUP", "2.0"))
    spmm_gedges_floor = float(os.environ.get("GAS_BENCH_MIN_SPMM_GEDGES", "0.02"))
    spmm_speedup_floor = float(os.environ.get("GAS_BENCH_MIN_SPMM_SPEEDUP", "2.0"))
    attn_gedges_floor = float(os.environ.get("GAS_BENCH_MIN_ATTN_GEDGES", "0.005"))
    attn_speedup_floor = float(os.environ.get("GAS_BENCH_MIN_ATTN_SPEEDUP", "1.2"))
    gemm_v16_floor = float(os.environ.get("GAS_BENCH_MIN_GEMM_V16_GFLOPS", "1.0"))
    spmm_v16_floor = float(os.environ.get("GAS_BENCH_MIN_SPMM_V16_GEDGES", "0.02"))
    step_budget_ms = float(os.environ.get("GAS_BENCH_MAX_STEP_MS", "2000"))
    overlap_floor = float(os.environ.get("GAS_BENCH_MIN_OVERLAP_SPEEDUP", "0.9"))
    codec_ratio_cap = float(os.environ.get("GAS_BENCH_MAX_CODEC_RATIO", "4.0"))
    ckpt_ratio_cap = float(os.environ.get("GAS_BENCH_MAX_CKPT_RATIO", "1.0"))

    medians = {r["name"]: r["median_ms"] for r in rec["results"]}

    def one(*subs):
        hits = [(k, v) for k, v in medians.items() if all(s in k for s in subs)]
        if len(hits) != 1:
            print(f"expected exactly one bench matching {subs}, got {hits}")
            raise SystemExit(2)
        return hits[0]

    failures = []
    for (kind, budget_ms) in [("history pull", pull_budget_ms), ("history push", push_budget_ms)]:
        name, ms = one(kind, "[sharded]")
        print(f"{name}: median {ms:.3f} ms (budget {budget_ms:.0f} ms)")
        if ms > budget_ms:
            failures.append(f"{name}: median {ms:.3f} ms over budget {budget_ms:.0f} ms")

    metrics = rec["metrics"]
    for key in ("pull_speedup_sharded_vs_serial", "push_speedup_sharded_vs_serial"):
        v = metrics[key]
        print(f"{key}: {v:.2f}x (floor {speedup_floor}x)")
        if v < speedup_floor:
            failures.append(f"{key} = {v:.2f}x below floor {speedup_floor}x")

    # GEMM section: every blocked shape must clear the GFLOP/s floor; the
    # big (n=10k) shapes must also clear the blocked-vs-scalar speedup floor
    for op in GEMM_OPS:
        for shape in GEMM_SHAPES:
            key = f"gemm_{op}_{shape}_blocked_gflops"
            v = metrics[key]
            print(f"{key}: {v:.2f} GFLOP/s (floor {gemm_gflops_floor})")
            if v < gemm_gflops_floor:
                failures.append(f"{key} = {v:.2f} GFLOP/s below floor {gemm_gflops_floor}")
        key = f"gemm_{op}_{GEMM_GATED_SHAPE}_speedup"
        v = metrics[key]
        print(f"{key}: {v:.2f}x (floor {gemm_speedup_floor}x)")
        if v < gemm_speedup_floor:
            failures.append(f"{key} = {v:.2f}x below floor {gemm_speedup_floor}x")

    # SpMM section: every blocked shape must clear the GEdge/s floor; the
    # big (n=10k) shapes must also clear the blocked-vs-scalar speedup floor
    for op in SPMM_OPS:
        for shape in SPMM_SHAPES:
            key = f"spmm_{op}_{shape}_blocked_gedges"
            v = metrics[key]
            print(f"{key}: {v:.3f} GEdge/s (floor {spmm_gedges_floor})")
            if v < spmm_gedges_floor:
                failures.append(f"{key} = {v:.3f} GEdge/s below floor {spmm_gedges_floor}")
        for shape in SPMM_GATED_SHAPES:
            key = f"spmm_{op}_{shape}_speedup"
            v = metrics[key]
            print(f"{key}: {v:.2f}x (floor {spmm_speedup_floor}x)")
            if v < spmm_speedup_floor:
                failures.append(f"{key} = {v:.2f}x below floor {spmm_speedup_floor}x")

    # edge-softmax attention: every blocked shape must clear the GEdge/s
    # floor; the big (n=10k) shapes must also beat the serial oracle
    for shape in ATTN_SHAPES:
        key = f"attn_fwd_{shape}_blocked_gedges"
        v = metrics[key]
        print(f"{key}: {v:.3f} GEdge/s (floor {attn_gedges_floor})")
        if v < attn_gedges_floor:
            failures.append(f"{key} = {v:.3f} GEdge/s below floor {attn_gedges_floor}")
    for shape in ATTN_GATED_SHAPES:
        key = f"attn_fwd_{shape}_speedup"
        v = metrics[key]
        print(f"{key}: {v:.2f}x (floor {attn_speedup_floor}x)")
        if v < attn_speedup_floor:
            failures.append(f"{key} = {v:.2f}x below floor {attn_speedup_floor}x")

    # kernel ISA dispatch: liveness first — the auto-dispatched row and
    # every forced-tier row must exist (a missing row means the dispatcher
    # or the forcing path silently stopped running), and the resolved auto
    # tier must be at least the 8-lane blocked path (Scalar is never
    # auto-selected; seeing 0 here means detection broke or someone left
    # GAS_KERNEL_ISA=scalar set in the CI environment)
    for tag in ("[isa auto]", "[isa scalar-forced]", "[isa v8-forced]", "[isa v16-forced]"):
        name, ms = one("gemm fwd n10k", tag)
        print(f"{name}: median {ms:.3f} ms (liveness)")
    for tag in ("[isa v8-forced]", "[isa v16-forced]"):
        name, ms = one("spmm fwd n10k_deg8", tag)
        print(f"{name}: median {ms:.3f} ms (liveness)")
    kernel_isa = metrics["kernel_isa"]
    print(f"kernel_isa: {kernel_isa:.0f} (0=scalar 1=v8 2=v16; floor 1)")
    if kernel_isa < 1.0:
        failures.append(f"kernel_isa = {kernel_isa:.0f}: auto dispatch resolved below the v8 tier")
    # per-tier throughput floors: only meaningful where the wide tier is
    # native — on an AVX2-only runner the v16 rows measure narrowed
    # codegen, so the floor is skipped (loudly, never silently)
    if metrics.get("kernel_isa_wide", 0.0) >= 1.0:
        for key, floor, unit in (
            ("gemm_fwd_n10k_v16_gflops", gemm_v16_floor, "GFLOP/s"),
            ("spmm_fwd_n10k_deg8_v16_gedges", spmm_v16_floor, "GEdge/s"),
        ):
            v = metrics[key]
            print(f"{key}: {v:.3f} {unit} (floor {floor})")
            if v < floor:
                failures.append(f"{key} = {v:.3f} {unit} below floor {floor}")
    else:
        print("wide tier not detected on this runner — v16 throughput floors skipped")

    # native per-model train steps: present (the artifact loaded and the
    # interpreter ran it) and within the hang budget. Keyed off the
    # bench's explicit backend marker — NOT off row presence, which would
    # let "every artifact failed to load" pass silently.
    step_rows = {k: v for k, v in medians.items() if "train step" in k}
    if metrics.get("backend_native", 0.0) == 1.0:
        for model in STEP_MODELS:
            hits = [(k, v) for k, v in step_rows.items() if model in k]
            if not hits:
                failures.append(f"no native train-step row for {model} — model not running?")
                continue
            name, ms = hits[0]
            print(f"{name}: median {ms:.3f} ms (budget {step_budget_ms:.0f} ms)")
            if ms > step_budget_ms:
                failures.append(f"{name}: median {ms:.3f} ms over budget {step_budget_ms:.0f} ms")
    else:
        print("non-native backend per the bench record — step budgets skipped")

    # quantized backings: dequantize-on-gather may cost, but a pull/push
    # through f16 or int8 must stay within a small constant factor of the
    # plain sharded f32 rows (same rows, ram media — pure codec overhead)
    for key in (
        "pull_f16_over_ram_ratio",
        "push_f16_over_ram_ratio",
        "pull_int8_over_ram_ratio",
        "push_int8_over_ram_ratio",
    ):
        v = metrics[key]
        print(f"{key}: {v:.2f}x (cap {codec_ratio_cap}x)")
        if v > codec_ratio_cap:
            failures.append(f"{key} = {v:.2f}x over cap {codec_ratio_cap}x")

    # crash tolerance must be near-free: writing the epoch-boundary
    # manifest (and loading it back on resume) is gated against the cost
    # of the epoch it protects, so checkpointing can never silently
    # double the training loop
    for key in ("ckpt_save_over_epoch_ratio", "ckpt_load_over_epoch_ratio"):
        v = metrics[key]
        print(f"{key}: {v:.3f}x of a serial epoch (cap {ckpt_ratio_cap}x)")
        if v > ckpt_ratio_cap:
            failures.append(f"{key} = {v:.3f}x over cap {ckpt_ratio_cap}x")

    # pipelined (pull_depth=2) epoch must not fall clearly behind serial
    # (loose floor; the overlap *win* is gated by the trajectory check)
    v = metrics["pipeline_overlap_speedup"]
    print(f"pipeline_overlap_speedup: {v:.2f}x (floor {overlap_floor}x)")
    if v < overlap_floor:
        failures.append(f"pipeline_overlap_speedup = {v:.2f}x below floor {overlap_floor}x")

    if failures:
        print("\nPERF GATE FAILED:")
        for msg in failures:
            print(f"  {msg}")
        return 1
    print("perf gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
