#!/usr/bin/env python3
"""Render the run_gate.py ledger as a markdown wall-time table.

Reads the JSONL ledger written by ci/run_gate.py and appends a per-gate
wall-time table to $GITHUB_STEP_SUMMARY (stdout when unset, so it is
useful locally too). Designed to run with `if: always()` — it reports the
gates that did run even when one of them failed, and a missing/empty
ledger is a note, not an error (the job may have died before any gate).

Usage: python3 ci/report_gate_times.py [gate_times.jsonl]
"""
import json
import os
import sys


def main() -> int:
    path = sys.argv[1] if len(sys.argv) > 1 else os.environ.get(
        "GAS_GATE_TIMES", "gate_times.jsonl"
    )
    rows = []
    if os.path.exists(path):
        with open(path) as f:
            for line in f:
                line = line.strip()
                if line:
                    rows.append(json.loads(line))

    lines = ["### CI gate wall times", ""]
    if not rows:
        lines.append(f"_no gate timings recorded ({path} missing or empty)_")
    else:
        lines.append("| gate | seconds | budget (s) | used | status |")
        lines.append("|---|---:|---:|---:|---|")
        total = 0.0
        for r in rows:
            seconds, budget, rc = r["seconds"], r["budget"], r["rc"]
            total += seconds
            used = f"{100.0 * seconds / budget:.0f}%" if budget > 0 else "-"
            if rc != 0:
                status = f"FAILED (rc={rc})"
            elif budget > 0 and seconds > budget:
                status = "OVER BUDGET"
            else:
                status = "ok"
            lines.append(
                f"| {r['name']} | {seconds:.1f} | {budget:.0f} | {used} | {status} |"
            )
        lines.append(f"| **total** | **{total:.1f}** | | | |")
    out = "\n".join(lines) + "\n"

    summary = os.environ.get("GITHUB_STEP_SUMMARY")
    if summary:
        with open(summary, "a") as f:
            f.write(out)
    print(out, end="")
    return 0


if __name__ == "__main__":
    sys.exit(main())
