#!/usr/bin/env python3
"""Gate the CI bench-smoke job on BENCH_fig3.json (native convergence).

The fig3 bench trains panel (a) — GCN-2 on the synthetic cora profile —
full-batch, naive-history and GAS, on the native backend (real fwd+bwd
compute, no PJRT). This gate fails when training stops learning: GAS final
validation accuracy below a floor (chance is 1/7 ~= 0.14), the GAS loss
not dropping, or GAS drifting away from the full-batch reference. The
budgets are deliberately loose — this catches "the backend broke", not
few-point accuracy drift. Overridable via env:

    GAS_FIG3_MIN_GAS_VAL     (default 0.30)
    GAS_FIG3_MAX_GAP         (default 0.25, |GAS - full| final val acc)
    GAS_FIG3_MAX_LOSS_RATIO  (default 0.80, final/first GAS train loss)

Usage: python3 ci/check_bench_fig3.py [BENCH_fig3.json]
"""
import json
import os
import sys


def main() -> int:
    path = sys.argv[1] if len(sys.argv) > 1 else "BENCH_fig3.json"
    with open(path) as f:
        rec = json.load(f)

    min_gas_val = float(os.environ.get("GAS_FIG3_MIN_GAS_VAL", "0.30"))
    max_gap = float(os.environ.get("GAS_FIG3_MAX_GAP", "0.25"))
    max_loss_ratio = float(os.environ.get("GAS_FIG3_MAX_LOSS_RATIO", "0.80"))

    m = rec["metrics"]
    failures = []

    gas_val = m["a_gas_val"]
    print(f"a_gas_val: {gas_val:.4f} (floor {min_gas_val})")
    if gas_val < min_gas_val:
        failures.append(f"GAS final val acc {gas_val:.4f} below floor {min_gas_val}")

    gap = abs(m["a_gas_full_gap"])
    print(f"|a_gas_full_gap|: {gap:.4f} (budget {max_gap})")
    if gap > max_gap:
        failures.append(f"|GAS - full| val gap {gap:.4f} over budget {max_gap}")

    ratio = m["a_gas_loss_ratio"]
    print(f"a_gas_loss_ratio: {ratio:.4f} (budget {max_loss_ratio})")
    if not ratio == ratio or ratio > max_loss_ratio:  # NaN-safe
        failures.append(f"GAS loss ratio {ratio} over budget {max_loss_ratio} (loss not dropping)")

    naive = m["a_naive_val"]
    print(f"a_naive_val: {naive:.4f} (sanity: finite)")
    if not naive == naive:
        failures.append("naive-history val acc is NaN")

    if failures:
        print("\nCONVERGENCE GATE FAILED:")
        for msg in failures:
            print(f"  {msg}")
        return 1
    print("convergence gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
