#!/usr/bin/env python3
"""Run one CI gate under a wall-time budget and record how long it took.

Every bench/gate step in the bench-smoke job runs through this wrapper so
CI wall time is a *measured, budgeted* quantity instead of folklore: the
step's duration lands in a JSONL ledger (rendered into the job summary by
ci/report_gate_times.py), and a step that overruns its budget fails the
job even when the gate itself passed — a silently slowing smoke is a perf
regression in the CI product surface, caught here rather than when the
job-level timeout-minutes starts flaking.

Budgets: the per-gate default is given on the command line; the env var
GAS_GATE_BUDGET_<NAME> (name upper-cased, '-' -> '_') overrides it, so a
known-slow runner class can loosen one gate without editing the workflow.
A budget <= 0 disables the overrun check (the duration is still recorded).

The ledger path defaults to gate_times.jsonl; GAS_GATE_TIMES overrides.
One JSON object per line: {"name", "seconds", "budget", "rc"}.

Exit code: the wrapped command's, or 1 if the command passed but overran
its budget.

Usage: python3 ci/run_gate.py NAME DEFAULT_BUDGET_S -- cmd [args...]
"""
import json
import os
import subprocess
import sys
import time


def main() -> int:
    argv = sys.argv[1:]
    if len(argv) < 4 or argv[2] != "--":
        print(__doc__)
        return 2
    name, default_budget = argv[0], float(argv[1])
    cmd = argv[3:]

    env_key = "GAS_GATE_BUDGET_" + name.upper().replace("-", "_")
    budget = float(os.environ.get(env_key, default_budget))

    start = time.monotonic()
    rc = subprocess.call(cmd)
    seconds = time.monotonic() - start

    ledger = os.environ.get("GAS_GATE_TIMES", "gate_times.jsonl")
    with open(ledger, "a") as f:
        f.write(json.dumps(
            {"name": name, "seconds": round(seconds, 3), "budget": budget, "rc": rc}
        ) + "\n")

    status = "ok" if rc == 0 else f"rc={rc}"
    print(f"[gate {name}] {seconds:.1f}s of {budget:.0f}s budget ({status})")
    if rc != 0:
        return rc
    if budget > 0 and seconds > budget:
        print(
            f"[gate {name}] BUDGET OVERRUN: {seconds:.1f}s > {budget:.0f}s "
            f"(override with {env_key}) — the gate passed but is too slow"
        )
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
