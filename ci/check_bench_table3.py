#!/usr/bin/env python3
"""Gate the CI bench-smoke job on BENCH_table3.json (out-of-core smoke).

The table3 bench trains the same gcnii8 schedule five times (in-RAM
serial, mmap serial, mmap concurrent, mmap+f16 serial, mmap+int8 serial)
on a planted graph whose histories deliberately overflow the RAM budget.
This script makes the out-of-core and compressed-storage claims
enforceable:

  * the run must not be vacuous — total history bytes must EXCEED the
    budget (otherwise "fits under budget" proves nothing), and the RAM
    backing's resident bytes must be >= the logical history size;
  * the mmap run's self-reported resident history bytes (heap the store
    cannot evict: staleness metadata) must fit UNDER the budget while its
    mapped bytes cover the full logical history;
  * the mmap run must be bit-for-bit equal to the RAM run — curves,
    staleness probes, push deltas, and every history row (the bench
    computes this; we gate on its verdict);
  * the quantized runs must actually compress: stored bytes of the
    encoded embedding block <= 0.55x logical for f16 and <= 0.30x for
    int8 (at h=64 the exact ratios are 0.5 and 0.28125; the caps leave
    room for per-shard GASQ headers), with a finite positive
    quantization-error telemetry reading and a finite final loss;
  * the whole bench must finish inside a wall-clock budget (near-hang
    guard, far looser than the job timeout).

Thresholds are overridable via env for local experimentation:

    GAS_BENCH_MAX_HISTORY_RSS_MB   (default 64; also read by the bench,
                                    which echoes it into the record)
    GAS_BENCH_MAX_TABLE3_WALL_S    (default 360)
    GAS_BENCH_MAX_F16_RATIO        (default 0.55)
    GAS_BENCH_MAX_INT8_RATIO       (default 0.30)

Usage: python3 ci/check_bench_table3.py [BENCH_table3.json]
"""
import json
import math
import os
import sys

MIB = float(1 << 20)

# the five wall-clock rows the bench must always emit
ROWS = (
    "table3 train gcnii8 [ram]",
    "table3 train gcnii8 [mmap]",
    "table3 train gcnii8 [mmap pull_depth=2]",
    "table3 train gcnii8 [mmap f16]",
    "table3 train gcnii8 [mmap int8]",
)


def main() -> int:
    path = sys.argv[1] if len(sys.argv) > 1 else "BENCH_table3.json"
    with open(path) as f:
        rec = json.load(f)

    budget_mb = float(os.environ.get("GAS_BENCH_MAX_HISTORY_RSS_MB", "64"))
    wall_budget_s = float(os.environ.get("GAS_BENCH_MAX_TABLE3_WALL_S", "360"))
    f16_ratio_cap = float(os.environ.get("GAS_BENCH_MAX_F16_RATIO", "0.55"))
    int8_ratio_cap = float(os.environ.get("GAS_BENCH_MAX_INT8_RATIO", "0.30"))

    medians = {r["name"]: r["median_ms"] for r in rec["results"]}
    metrics = rec["metrics"]
    failures = []

    for name in ROWS:
        if name not in medians:
            failures.append(f"missing bench row {name!r} — a backing did not run")
        else:
            print(f"{name}: {medians[name] / 1e3:.1f} s")

    total_mb = metrics["history_total_bytes"] / MIB
    ram_resident_mb = metrics["ram_resident_bytes"] / MIB
    mmap_resident_mb = metrics["mmap_resident_bytes"] / MIB
    mmap_mapped_mb = metrics["mmap_mapped_bytes"] / MIB
    print(f"history total: {total_mb:.1f} MiB (budget {budget_mb:.0f} MiB)")
    print(f"ram resident: {ram_resident_mb:.1f} MiB")
    print(f"mmap resident: {mmap_resident_mb:.1f} MiB | mapped {mmap_mapped_mb:.1f} MiB")

    # not vacuous: the workload genuinely does not fit in the budget
    if total_mb <= budget_mb:
        failures.append(
            f"history total {total_mb:.1f} MiB fits the {budget_mb:.0f} MiB budget — "
            "out-of-core smoke is vacuous; grow the graph or shrink the budget"
        )
    if ram_resident_mb < total_mb:
        failures.append(
            f"ram backing resident {ram_resident_mb:.1f} MiB < logical {total_mb:.1f} MiB — "
            "residency accounting is broken"
        )

    # the out-of-core claim: unevictable heap under budget, file holds the rest
    if mmap_resident_mb > budget_mb:
        failures.append(
            f"mmap resident history {mmap_resident_mb:.1f} MiB over the "
            f"{budget_mb:.0f} MiB budget — backing is not out-of-core"
        )
    if mmap_mapped_mb < total_mb:
        failures.append(
            f"mmap mapped {mmap_mapped_mb:.1f} MiB < logical {total_mb:.1f} MiB — "
            "shard files do not cover the history"
        )

    # the compression claim: quantized backings store the encoded block
    # well under the f32 logical size, and the error telemetry is live
    for label, cap in [("f16", f16_ratio_cap), ("int8", int8_ratio_cap)]:
        ratio = metrics[f"{label}_stored_ratio"]
        qmax = metrics[f"{label}_quant_err_max"]
        qmean = metrics[f"{label}_quant_err_mean"]
        loss = metrics[f"{label}_final_loss"]
        print(f"{label}: stored/logical {ratio:.4f} (cap {cap}), "
              f"qerr max {qmax:.3e} mean {qmean:.3e}, final loss {loss:.4f}")
        if ratio > cap:
            failures.append(
                f"{label} stored/logical {ratio:.4f} over the {cap} cap — "
                "codec is not compressing the stored history"
            )
        if not (0.0 < qmean <= qmax):
            failures.append(
                f"{label} quantization telemetry broken: mean {qmean:.3e}, "
                f"max {qmax:.3e} (expected 0 < mean <= max)"
            )
        if not math.isfinite(loss):
            failures.append(f"{label} final loss is not finite — training diverged")

    # the correctness claim: same schedule, same bits
    if metrics["mmap_equals_ram"] != 1.0:
        failures.append("mmap run is NOT bit-for-bit equal to the ram run")
    else:
        print("mmap == ram bit-for-bit: ok")

    wall_s = metrics["wall_s"]
    print(f"bench wall clock: {wall_s:.1f} s (budget {wall_budget_s:.0f} s)")
    if wall_s > wall_budget_s:
        failures.append(f"bench took {wall_s:.1f} s, over the {wall_budget_s:.0f} s budget")

    if failures:
        print("\nOUT-OF-CORE GATE FAILED:")
        for msg in failures:
            print(f"  {msg}")
        return 1
    print("out-of-core gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
