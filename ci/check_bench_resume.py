#!/usr/bin/env python3
"""Kill-and-resume CI gate: SIGKILL a real training run, resume it, and
require the resumed run to land on the *bit-identical* final state of an
uninterrupted reference run.

This is the one recovery test the in-process suite cannot perform: the
`checkpoint` integration tests simulate the kill with `stop_after_epoch`
(a clean break inside one process), while this gate delivers an actual
`SIGKILL` to a separate `gas train` process mid-epoch — no destructors,
no flush-on-exit, nothing but what the epoch-boundary manifest already
made durable. The contract under test is the tentpole claim: on the
deterministic schedule (Serial pipeline, pull_depth=1), kill + resume
reproduces the uninterrupted run's FINAL fingerprint line exactly —
f64 `to_bits` of the loss/val/test curves, the step count, and CRC-32s
over the parameter tensors and raw history bytes.

Sequence:
  1. reference: `gas train` to completion, no checkpointing; parse FINAL
  2. victim:    same command + --checkpoint-dir; wait for the first
                manifest to appear (>= 1 epoch made durable), then
                os.kill(pid, SIGKILL)
  3. resumed:   same command + --checkpoint-dir --resume, to completion
  4. compare every FINAL field bit-for-bit; write BENCH_resume.json

Env:
    GAS_BIN             path to the gas binary (default target/release/gas)
    GAS_RESUME_EPOCHS   training length (default 12 — long enough that the
                        victim is still mid-run when the kill lands)
    GAS_RESUME_TIMEOUT  per-phase wall-time cap in seconds (default 300)

Usage: python3 ci/check_bench_resume.py [OUT.json]
"""
import json
import os
import signal
import subprocess
import sys
import tempfile
import time

TRAIN_ARGS = [
    "train", "--dataset", "cora", "--model", "gcn2", "--mode", "gas",
    "--lr", "0.01", "--reg", "0.02", "--seed", "7",
    "--pipeline", "serial", "--pull-depth", "1",
]


def parse_final(stdout: str, who: str) -> dict:
    for line in stdout.splitlines():
        if line.startswith("FINAL "):
            fields = dict(tok.split("=", 1) for tok in line.split()[1:])
            print(f"[{who}] {line}")
            return fields
    print(f"[{who}] no FINAL line in output:\n{stdout}")
    raise SystemExit(2)


def run_to_completion(cmd, timeout: float, who: str) -> tuple:
    start = time.monotonic()
    proc = subprocess.run(
        cmd, stdout=subprocess.PIPE, stderr=subprocess.STDOUT,
        text=True, timeout=timeout,
    )
    seconds = time.monotonic() - start
    if proc.returncode != 0:
        print(f"[{who}] exited rc={proc.returncode}:\n{proc.stdout}")
        raise SystemExit(2)
    return parse_final(proc.stdout, who), seconds


def row(name: str, seconds: float) -> dict:
    ms = seconds * 1e3
    return {
        "name": name, "iters": 1,
        "mean_ms": ms, "std_ms": 0.0, "median_ms": ms, "min_ms": ms,
    }


def main() -> int:
    out_path = sys.argv[1] if len(sys.argv) > 1 else "BENCH_resume.json"
    gas_bin = os.environ.get("GAS_BIN", "target/release/gas")
    epochs = int(os.environ.get("GAS_RESUME_EPOCHS", "12"))
    timeout = float(os.environ.get("GAS_RESUME_TIMEOUT", "300"))

    workdir = tempfile.mkdtemp(prefix="gas-resume-gate-")
    ck_dir = os.path.join(workdir, "ckpt")
    manifest = os.path.join(ck_dir, "checkpoint.gask")
    base_cmd = [gas_bin] + TRAIN_ARGS + ["--epochs", str(epochs)]
    ck_cmd = base_cmd + ["--checkpoint-dir", ck_dir, "--checkpoint-every", "1"]

    # 1. the uninterrupted reference run
    ref, ref_s = run_to_completion(base_cmd, timeout, "reference")

    # 2. the victim: SIGKILL as soon as the first manifest is durable —
    #    mid-epoch, destructors never run, shard files possibly torn
    start = time.monotonic()
    victim = subprocess.Popen(
        ck_cmd, stdout=subprocess.DEVNULL, stderr=subprocess.DEVNULL,
    )
    killed = False
    while time.monotonic() - start < timeout:
        if os.path.exists(manifest):
            os.kill(victim.pid, signal.SIGKILL)
            killed = True
            break
        if victim.poll() is not None:
            print(
                f"[victim] finished (rc={victim.returncode}) before a manifest "
                f"appeared — checkpointing is not writing {manifest}"
            )
            return 2
        time.sleep(0.02)
    victim.wait(timeout=timeout)
    kill_s = time.monotonic() - start
    if not killed:
        print(f"[victim] no manifest within {timeout:.0f}s — gate cannot kill")
        return 2
    if victim.returncode == 0:
        print("[victim] exited cleanly despite the SIGKILL — kill landed too late")
        return 2
    print(f"[victim] SIGKILLed {kill_s:.2f}s in (rc={victim.returncode})")

    # 3. resume from whatever the manifest captured
    res, res_s = run_to_completion(ck_cmd + ["--resume"], timeout, "resumed")

    # 4. the bit-equality verdict
    failures = []
    for key in ("loss_bits", "val_bits", "test_bits", "steps", "params_crc", "hist_crc"):
        a, b = ref.get(key), res.get(key)
        if a is None or b is None:
            failures.append(f"{key}: missing from a FINAL line (ref={a!r} resumed={b!r})")
        elif a != b:
            failures.append(f"{key}: reference {a} != resumed {b}")

    record = {
        "bench": "resume",
        "results": [
            row("resume reference run (uninterrupted)", ref_s),
            row("resume victim run (train to SIGKILL)", kill_s),
            row("resume recovered run (manifest to done)", res_s),
        ],
        "metrics": {
            "bit_identical": 0.0 if failures else 1.0,
            "epochs": float(epochs),
        },
    }
    with open(out_path, "w") as f:
        json.dump(record, f, indent=2)
        f.write("\n")
    print(f"wrote {out_path}")

    if failures:
        print("\nRESUME GATE FAILED (killed+resumed run diverged from reference):")
        for msg in failures:
            print(f"  {msg}")
        return 1
    print("resume gate passed: killed+resumed run is bit-identical to the reference")
    return 0


if __name__ == "__main__":
    sys.exit(main())
