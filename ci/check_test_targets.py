#!/usr/bin/env python3
"""Fail CI when a test file exists that cargo will never run.

`rust/tests/` is NOT auto-discovered: the workspace sets `autotests =
false`, so every integration-test file needs an explicit `[[test]]` entry
in Cargo.toml. PR 3 shipped `gemm_prop.rs` without one and the suite
silently never ran in CI until PR 4 noticed — this check makes that class
of omission impossible. It also flags the reverse (a `[[test]]` entry
whose path no longer exists, which `cargo build` would catch later and
more confusingly), and the same drift for `benches/` (`autobenches =
false` too).

Usage: python3 ci/check_test_targets.py [repo-root]
"""
import os
import re
import sys


def registered(manifest: str, section: str) -> dict:
    """Map of path -> name for every [[<section>]] entry in Cargo.toml."""
    out = {}
    blocks = re.split(r"^\[", manifest, flags=re.M)
    for block in blocks:
        if not block.startswith(f"[{section}]]"):
            continue
        name = re.search(r'^name\s*=\s*"([^"]+)"', block, flags=re.M)
        path = re.search(r'^path\s*=\s*"([^"]+)"', block, flags=re.M)
        if path:
            out[path.group(1)] = name.group(1) if name else "?"
    return out


def main() -> int:
    root = sys.argv[1] if len(sys.argv) > 1 else "."
    with open(os.path.join(root, "Cargo.toml")) as f:
        manifest = f.read()

    failures = []
    for section, d in [("test", "rust/tests"), ("bench", "benches")]:
        entries = registered(manifest, section)
        on_disk = sorted(
            f"{d}/{name}"
            for name in os.listdir(os.path.join(root, d))
            if name.endswith(".rs")
        )
        for path in on_disk:
            if path not in entries:
                failures.append(
                    f"{path} has no [[{section}]] entry in Cargo.toml — "
                    f"it will never build or run in CI"
                )
        for path in entries:
            if not os.path.exists(os.path.join(root, path)):
                failures.append(
                    f"Cargo.toml [[{section}]] entry points at missing file {path}"
                )
        print(f"[[{section}]]: {len(on_disk)} files on disk, {len(entries)} registered")

    if failures:
        print("\nTEST-TARGET GATE FAILED:")
        for msg in failures:
            print(f"  {msg}")
        return 1
    print("every test/bench file is a registered cargo target")
    return 0


if __name__ == "__main__":
    sys.exit(main())
