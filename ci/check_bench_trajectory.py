#!/usr/bin/env python3
"""Gate PRs on the bench *trajectory*: fresh BENCH_*.json vs the committed
previous run.

The bench-smoke job commits its BENCH_micro.json / BENCH_fig3.json to
`ci/trajectory/` on every push to main (see .github/workflows/ci.yml), so
every PR can compare its freshly-measured medians against the last
known-good run of the same tiny-mode smoke on the same runner class. Any
*gated* median that regresses by more than the threshold fails the job —
perf is a product surface, and a 25% step is a code change, not runner
noise smeared over a single sample (absolute budgets in
check_bench_micro.py already catch order-of-magnitude blowups; this
catches the slow bleed).

Rules:
  * missing baseline  -> pass (first run on a fresh branch history)
  * tiny-mode mismatch between fresh and baseline -> pass with a note
    (the records are not comparable)
  * kernel-ISA tier mismatch -> pass with a note: micro records carry the
    resolved dispatch tier as the `kernel_isa` metric, and a baseline
    measured on a different tier (scalar/v8/v16) prices every kernel row
    differently; the main-only refresh step re-keys the baseline
  * toolchain mismatch -> pass with a note: when the workflow exports
    GAS_BENCH_TRAJ_FINGERPRINT (the rustc version) and the committed
    FINGERPRINT next to the baseline differs, kernel codegen changed under
    the baseline's feet and the medians are not comparable; the main-only
    refresh step rewrites both together
  * gated medians: only bench rows matching GATED_SUBSTRINGS for that
    bench name, and only rows above MIN_GATED_MS (sub-millisecond medians
    are timer noise)
  * regression = fresh_median / baseline_median - 1 > threshold, AND the
    row's min must regress past the threshold too (when both records
    carry min_ms): a noisy neighbor inflates the median of a 5-sample
    run long before it inflates the min, so requiring both filters
    single-run flakes. Residual risk — a genuinely slower runner
    generation shifts both — is accepted: the threshold is loose, the
    env override exists, and main refreshes the baseline every push.

Env overrides:
    GAS_BENCH_TRAJ_MAX_REGRESSION  (default 0.25)
    GAS_BENCH_TRAJ_MIN_MS          (default 1.0)
    GAS_BENCH_TRAJ_FINGERPRINT     (default: skip the fingerprint check)

Usage: python3 ci/check_bench_trajectory.py FRESH.json BASELINE.json
"""
import json
import os
import sys

# substrings selecting the gated rows per bench record name; everything
# else (scalar oracle baselines, probe micro-rows) is informational
GATED_SUBSTRINGS = {
    "micro": [
        "history pull 8K rows x3 layers [sharded]",
        "history push 4x8K rows + drain [sharded]",
        "history pull 8K rows x3 layers [mmap]",
        "history push 4x8K rows + drain [mmap]",
        "history pull 8K rows x3 layers [f16]",
        "history push 4x8K rows + drain [f16]",
        "history pull 8K rows x3 layers [int8]",
        "history push 4x8K rows + drain [int8]",
        "[blocked]",          # every blocked GEMM, SpMM and edge-softmax row
        # (the attn softmax rows ride the "[blocked]" substring — their
        # "[scalar]" oracle baselines stay informational, like GEMM/SpMM's)
        "train step",         # the per-model end-to-end native steps
        "batch assembly",
        "pipeline epoch",     # serial + pull_depth=2 software-pipeline rows
        "checkpoint",         # manifest save + resume-load rows: the cost
                              # of crash tolerance is a product surface
    ],
    # the kill-and-resume gate's wall-clock rows (train / kill / resume
    # phases of the tiny SIGKILL drill); the bit-equality itself is gated
    # absolutely by check_bench_resume.py, this tracks how long the
    # recovery drill takes
    "resume": [
        "",
    ],
    # fig3 emits no timed rows today (metrics only, gated absolutely by
    # check_bench_fig3.py); listing it keeps the trajectory file tracked
    # and gates any timed rows the bench grows later
    "fig3_convergence": [
        "",                   # every timed row fig3 emits
    ],
    # table3's out-of-core smoke: the five end-to-end train rows
    # (ram / mmap serial / mmap concurrent / mmap f16 / mmap int8);
    # correctness + residency + compression are gated absolutely by
    # check_bench_table3.py, this tracks wall clock
    "table3_memory": [
        "table3 train",
    ],
    # error_bounds' quantized-convergence sweep: the six equal-step
    # "codec train {model} [{codec}]" rows; the accuracy-vs-f32 epsilon
    # is gated absolutely by check_bench_error_bounds.py, this tracks
    # the wall clock of the codec cells
    "error_bounds": [
        "codec train",
    ],
    # table2's staleness-control sweep: the four equal-budget
    # "table2 train gcnii8 cora [<arm>]" rows (round-robin / staleness /
    # delta-skip / refresh); accuracy parity + knob liveness are gated
    # absolutely by check_bench_table2.py, this tracks the wall clock —
    # the refresh row in particular, whose between-epoch forward passes
    # are the one arm that adds real compute
    "table2_ablation": [
        "table2 train",
    ],
}


def gated(bench: str, name: str) -> bool:
    subs = GATED_SUBSTRINGS.get(bench)
    if subs is None:
        return False
    return any(s in name for s in subs)


def main() -> int:
    if len(sys.argv) != 3:
        print(__doc__)
        return 2
    fresh_path, base_path = sys.argv[1], sys.argv[2]
    threshold = float(os.environ.get("GAS_BENCH_TRAJ_MAX_REGRESSION", "0.25"))
    min_ms = float(os.environ.get("GAS_BENCH_TRAJ_MIN_MS", "1.0"))

    with open(fresh_path) as f:
        fresh = json.load(f)
    if not os.path.exists(base_path):
        print(f"no committed baseline at {base_path} — trajectory starts here, passing")
        return 0
    with open(base_path) as f:
        base = json.load(f)

    bench = fresh.get("bench", "?")
    if base.get("bench") != bench:
        print(f"baseline is for bench {base.get('bench')!r}, fresh is {bench!r} — skipping")
        return 0
    if fresh.get("metrics", {}).get("tiny") != base.get("metrics", {}).get("tiny"):
        print("tiny-mode mismatch between fresh and baseline — records not comparable, skipping")
        return 0
    # micro records carry the resolved kernel-ISA tier (0=scalar 1=v8
    # 2=v16); a baseline measured on a different tier (runner generation
    # changed, or a forced GAS_KERNEL_ISA run was committed) prices every
    # kernel row differently, so the medians are not comparable — the
    # main-only refresh step will re-key the baseline on the new tier
    if fresh.get("metrics", {}).get("kernel_isa") != base.get("metrics", {}).get("kernel_isa"):
        print(
            "kernel-ISA tier mismatch between fresh and baseline "
            f"({base.get('metrics', {}).get('kernel_isa')!r} -> "
            f"{fresh.get('metrics', {}).get('kernel_isa')!r}) — "
            "records not comparable, skipping until main refreshes the baseline"
        )
        return 0
    fingerprint = os.environ.get("GAS_BENCH_TRAJ_FINGERPRINT", "")
    fp_path = os.path.join(os.path.dirname(base_path) or ".", "FINGERPRINT")
    if fingerprint and os.path.exists(fp_path):
        with open(fp_path) as f:
            base_fp = f.read().strip()
        if base_fp and base_fp != fingerprint:
            print(
                f"toolchain fingerprint changed ({base_fp!r} -> {fingerprint!r}) — "
                "baseline medians not comparable, skipping until main refreshes them"
            )
            return 0

    base_rows = {r["name"]: r for r in base.get("results", [])}
    failures = []
    checked = 0
    for r in fresh.get("results", []):
        name, ms = r["name"], r["median_ms"]
        if not gated(bench, name):
            continue
        prev_row = base_rows.get(name)
        if prev_row is None:
            print(f"  new gated row (no baseline): {name}: {ms:.3f} ms")
            continue
        prev = prev_row["median_ms"]
        if prev < min_ms and ms < min_ms:
            continue  # both below the timer-noise floor
        checked += 1
        ratio = ms / prev if prev > 0 else float("inf")
        regressed = ratio - 1.0 > threshold
        # median regressions must be corroborated by the min (when
        # recorded): single-run median noise does not move the min
        if regressed and "min_ms" in r and "min_ms" in prev_row and prev_row["min_ms"] > 0:
            min_ratio = r["min_ms"] / prev_row["min_ms"]
            if min_ratio - 1.0 <= threshold:
                print(
                    f"  {name}: median {prev:.3f} -> {ms:.3f} ms ({ratio:.2f}x) but min "
                    f"{prev_row['min_ms']:.3f} -> {r['min_ms']:.3f} ms ({min_ratio:.2f}x) "
                    "— treating as runner noise"
                )
                regressed = False
        if regressed or ratio - 1.0 <= threshold:
            flag = "REGRESSED" if regressed else "ok"
            print(f"  {name}: {prev:.3f} -> {ms:.3f} ms ({ratio:.2f}x) {flag}")
        if regressed:
            failures.append(
                f"{name}: median {prev:.3f} -> {ms:.3f} ms "
                f"(+{(ratio - 1.0) * 100:.0f}% > {threshold * 100:.0f}%)"
            )

    print(f"{bench}: {checked} gated medians compared against {base_path}")
    if failures:
        print("\nTRAJECTORY GATE FAILED:")
        for msg in failures:
            print(f"  {msg}")
        return 1
    print("trajectory gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
