//! Expressive-GNN scaling (paper §6.1 Fig. 3c + Table 7): a 4-layer GIN —
//! maximally expressive, sum aggregation, the worst case for history
//! staleness (Lemma 1's |N(v)| factor) — on the CLUSTER-style SBM
//! supergraph, with the two GAS techniques toggled.
//!
//!     cargo run --release --example expressive_gin

use gas::config::Ctx;
use gas::history::PipelineMode;
use gas::sched::batch::LabelSel;
use gas::sched::SchedulePolicy;
use gas::train::trainer::{PartitionKind, RefreshBy, TrainConfig, Trainer};

fn run(ctx: &mut Ctx, metis: bool, reg: bool, epochs: usize) -> anyhow::Result<(f64, f64)> {
    let (ds, art) = ctx.pair("cluster", "cluster_gin4_gas")?;
    let cfg = TrainConfig {
        epochs,
        lr: 0.005,
        clip: Some(1.0),
        reg_lambda: if reg { 0.05 } else { 0.0 },
        noise_scale: 0.1,
        weight_decay: 0.0,
        partitioner: if metis { PartitionKind::Metis } else { PartitionKind::Random },
        pipeline: PipelineMode::Concurrent,
        seed: 0,
        eval_every: epochs,
        shuffle: true,
        label_sel: LabelSel::Train,
        parts: None,
        history_shards: None,
        history_backing: gas::config::default_history_backing(),
        pull_depth: gas::config::default_pull_depth(),
        // the two paper techniques are the only toggles here: keep the
        // staleness control loop off
        sched_policy: SchedulePolicy::RoundRobin,
        refresh_top_k: 0,
        refresh_by: RefreshBy::Staleness,
        push_delta_min: 0.0,
        delta_tracking: true,
        checkpoint_dir: None,
        checkpoint_every: 1,
        resume: false,
        stop_after_epoch: None,
        fault: None,
    };
    let mut t = Trainer::new(ds, art, cfg)?;
    let r = t.train()?;
    Ok((r.val_acc.last().unwrap_or(0.0), r.test_at_best_val))
}

fn main() -> anyhow::Result<()> {
    let mut ctx = Ctx::new()?;
    let epochs: usize = std::env::var("GAS_EPOCHS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(15);
    println!("4-layer GIN on CLUSTER-style SBM supergraph ({} epochs)", epochs);
    println!("{:<34} {:>8} {:>8}", "configuration", "val", "test");
    for (metis, reg, name) in [
        (false, false, "baseline (random batches)"),
        (true, false, "+ METIS inter-connectivity min"),
        (true, true, "+ Lipschitz regularization (GAS)"),
    ] {
        let (va, te) = run(&mut ctx, metis, reg, epochs)?;
        println!("{name:<34} {va:>8.4} {te:>8.4}");
    }
    Ok(())
}
