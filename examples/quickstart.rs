//! Quickstart: train GCN on the Cora-profile graph with GAS and compare
//! against full-batch — the paper's headline claim (Table 1) in ~30 lines.
//!
//!     cargo run --release --example quickstart

use gas::baselines::naive_history::gas_config;
use gas::config::Ctx;
use gas::train::{FullBatchTrainer, Trainer};

fn main() -> anyhow::Result<()> {
    let mut ctx = Ctx::new()?;
    let epochs = 30;

    // --- full-batch reference ---------------------------------------------
    let (ds, art) = ctx.pair("cora", "cora_gcn2_full")?;
    let mut full = FullBatchTrainer::new(ds, art, 0.01, Some(1.0), 0.0, 0)?;
    let rf = full.train(epochs, 1)?;

    // --- GAS: METIS mini-batches + historical embeddings -------------------
    let (ds, art) = ctx.pair("cora", "cora_gcn2_gas")?;
    let mut trainer = Trainer::new(ds, art, gas_config(epochs, 0.01, 0.0, 0))?;
    let rg = trainer.train()?;

    println!("\n== GCN on cora ({} epochs) ==", epochs);
    println!(
        "full-batch : loss={:.4} val={:.4} test@best={:.4}",
        rf.loss.last().unwrap(),
        rf.val_acc.last().unwrap(),
        rf.test_at_best_val
    );
    println!(
        "GAS        : loss={:.4} val={:.4} test@best={:.4}  (histories: {:.1} MB host RAM, staleness {:.2} steps)",
        rg.loss.last().unwrap(),
        rg.val_acc.last().unwrap(),
        rg.test_at_best_val,
        rg.history_bytes as f64 / 1e6,
        rg.staleness[0],
    );
    let gap = (rg.test_at_best_val - rf.test_at_best_val).abs();
    println!("accuracy gap: {:.3} (paper: GAS closely matches full-batch)", gap);
    Ok(())
}
