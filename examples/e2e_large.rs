//! End-to-end validation driver (DESIGN.md §8): train GCN with GAS on the
//! largest profile (products, 120K nodes / ~1.8M directed edges, 96 METIS
//! parts) for several epochs (hundreds of optimizer steps), logging the
//! loss curve, step timing decomposition, history staleness and memory —
//! proving all three layers compose on a real workload.
//!
//!     cargo run --release --example e2e_large          # ~5 min
//!     GAS_EPOCHS=2 cargo run --release --example e2e_large

use gas::baselines::naive_history::gas_config;
use gas::config::Ctx;
use gas::memaccount::MemoryModel;
use gas::runtime::Executor;
use gas::train::Trainer;
use gas::util::timer::Timer;

fn main() -> anyhow::Result<()> {
    let epochs: usize = std::env::var("GAS_EPOCHS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(5);
    let mut ctx = Ctx::new()?;
    let t = Timer::start();
    let (ds, art) = ctx.pair("products", "products_gcn2_gas")?;
    println!(
        "dataset: products-profile n={} e={} parts={} | artifact nb={} nh={} e={} (setup {:.1}s)",
        ds.n(),
        ds.graph.num_directed_edges(),
        ds.profile.parts,
        art.spec().nb,
        art.spec().nh,
        art.spec().e,
        t.elapsed_s()
    );
    let mem = MemoryModel::new(ds, art.spec().layers, art.spec().h);
    println!(
        "device memory model: full-batch {:.2} GiB vs GAS {:.3} GiB (histories {:.1} MB in host RAM)",
        mem.full_batch().gib(),
        mem.gas(ds.profile.parts, 0).gib(),
        (art.spec().hist_layers() * ds.n() * art.spec().hist_dim * 4) as f64 / 1e6,
    );

    let mut cfg = gas_config(epochs, 0.01, 0.0, 0);
    cfg.eval_every = 1;
    let mut trainer = Trainer::new(ds, art, cfg)?;
    let t = Timer::start();
    let r = trainer.train()?;
    let train_s = t.elapsed_s();

    println!("\nloss curve ({} steps total):", r.steps);
    for (i, l) in r.loss.values.iter().enumerate() {
        let acc = r.val_acc.values.get(i).copied().unwrap_or(f64::NAN);
        println!("  epoch {:>2}: loss={:.4} val_acc={:.4}", i + 1, l, acc);
    }
    println!(
        "\nfinal: val={:.4} test@best={:.4} | {:.1}s total, {:.0} ms/step",
        r.val_acc.last().unwrap_or(0.0),
        r.test_at_best_val,
        train_s,
        train_s * 1e3 / r.steps as f64
    );
    println!("step decomposition:");
    for (k, v) in r.buckets.entries() {
        println!("  {k:<12} {:>8.2}s", v);
    }
    println!("staleness (steps): {:?}", r.staleness);
    println!("push delta (empirical epsilon): {:?}", r.push_delta);
    Ok(())
}
