//! Deep-GNN scaling (paper §6.1, Fig. 3b): a 64-layer GCNII trained with
//! GAS. Without histories the computation graph of a 64-layer GNN covers
//! the whole graph for every batch; with GAS it stays one hop deep.
//! Compares GAS vs the naive history baseline (random batches, no reg,
//! no clipping) — the gap is the paper's Fig. 3b story.
//!
//!     cargo run --release --example deep_gcnii

use gas::baselines::naive_history::{gas_config, naive_config};
use gas::config::Ctx;
use gas::runtime::Executor;
use gas::train::Trainer;

fn main() -> anyhow::Result<()> {
    let mut ctx = Ctx::new()?;
    let epochs: usize = std::env::var("GAS_EPOCHS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(20);

    let (ds, art) = ctx.pair("cora", "cora_gcnii64_gas_deep")?;
    println!("64-layer GCNII, cora profile, {} epochs", epochs);
    println!(
        "GAS memory note: histories = {} layers x {} nodes x {} dims (host RAM)",
        art.spec().hist_layers(),
        ds.n(),
        art.spec().hist_dim
    );

    let mut naive = Trainer::new(ds, art, naive_config(epochs, 0.01, 0))?;
    let rn = naive.train()?;

    let (ds, art) = ctx.pair("cora", "cora_gcnii64_gas_deep")?;
    let mut gas_tr = Trainer::new(ds, art, gas_config(epochs, 0.01, 0.05, 0))?;
    let rg = gas_tr.train()?;

    println!("\nnaive history : val={:.4} test@best={:.4} (mean push delta l1={:.4})",
        rn.val_acc.last().unwrap(), rn.test_at_best_val, rn.push_delta[0]);
    println!("GAS           : val={:.4} test@best={:.4} (mean push delta l1={:.4})",
        rg.val_acc.last().unwrap(), rg.test_at_best_val, rg.push_delta[0]);
    println!("\nper-epoch val accuracy (naive vs GAS):");
    for (i, (a, b)) in rn.val_acc.values.iter().zip(rg.val_acc.values.iter()).enumerate() {
        println!("  epoch {:>3}: {:.4}  {:.4}", i + 1, a, b);
    }
    Ok(())
}
